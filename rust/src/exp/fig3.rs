//! Figure 3: effect of the lookahead L on MNIST 8vs9 — mean ± std test
//! accuracy over random permutations of the stream order, still one pass.
//!
//! The paper's two observations to reproduce: accuracy rises with L and
//! converges by L ≈ 10, and the std over stream orders *shrinks* as L
//! grows (lookahead buys robustness to bad orderings).

use crate::bench_util::Table;
use crate::data::registry::load_dataset_sized;
use crate::data::Example;
use crate::error::Result;
use crate::eval::{accuracy, mean_std};
use crate::exp::ExpScale;
use crate::rng::Pcg32;
use crate::svm::lookahead::LookaheadSvm;
use crate::svm::TrainOptions;

/// Default L sweep (paper sweeps into the tens; 1 = Algorithm 1).
pub const DEFAULT_LS: [usize; 8] = [1, 2, 3, 5, 10, 20, 50, 100];

/// One sweep point.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub l: usize,
    pub mean: f64,
    pub std: f64,
    pub mean_support: f64,
}

/// Run the sweep on `dataset` (paper: mnist89) with `perms` permutations
/// per L (paper: 100).
pub fn run(dataset: &str, ls: &[usize], perms: usize, scale: &ExpScale) -> Result<Vec<SweepPoint>> {
    let ds = load_dataset_sized(dataset, scale.seed, scale.train_frac)?;
    let c = crate::exp::table1::c_for(dataset);
    let mut out = Vec::new();
    for &l in ls {
        let opts = TrainOptions::default().with_c(c).with_lookahead(l);
        let mut accs = Vec::with_capacity(perms);
        let mut supports = Vec::with_capacity(perms);
        for p in 0..perms {
            let mut order: Vec<usize> = (0..ds.train.len()).collect();
            Pcg32::new(scale.seed + p as u64, 0xF16_3).shuffle(&mut order);
            let stream: Vec<Example> = order.iter().map(|&i| ds.train[i].clone()).collect();
            let model = LookaheadSvm::fit(stream.iter(), ds.dim, &opts);
            accs.push(accuracy(&model, &ds.test));
            supports.push(model.num_support() as f64);
        }
        let (mean, std) = mean_std(&accs);
        let (mean_support, _) = mean_std(&supports);
        out.push(SweepPoint { l, mean, std, mean_support });
    }
    Ok(out)
}

/// Print the sweep as the figure's table.
pub fn print(points: &[SweepPoint]) {
    let mut t = Table::new(&["L", "acc mean %", "acc std %", "mean #SV"]);
    for p in points {
        t.row(&[
            p.l.to_string(),
            format!("{:.2}", p.mean * 100.0),
            format!("{:.2}", p.std * 100.0),
            format!("{:.0}", p.mean_support),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_sweep() {
        let pts = run(
            "mnist89",
            &[1, 5],
            3,
            &ExpScale { train_frac: 0.02, runs: 1, seed: 5 },
        )
        .unwrap();
        assert_eq!(pts.len(), 2);
        for p in &pts {
            assert!((0.0..=1.0).contains(&p.mean));
            assert!(p.std >= 0.0);
            assert!(p.mean_support >= 1.0);
        }
    }
}
