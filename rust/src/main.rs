//! StreamSVM CLI — the leader entrypoint.
//!
//! Flags accept both `--key value` and `--key=value`.
//!
//! ```text
//! streamsvm train    --dataset mnist89 [--lookahead 10] [--c 10] [--mode filter|scan|pure]
//!                    [--variant ball|lookahead|kernelized|ellipsoid|multiball]
//!                    [--shards 4] [--out model.meb] [--ckpt run.meb --ckpt-every 100000]
//!                    [--workers 4]  (multicore one-pass ingest; merge-tree fold at the end)
//!                    [--sparse true]   (convert the stream to the O(nnz) sparse path)
//!                    [--hash-dim 4096 [--hash-seed 24301]]  (signed feature hashing to D)
//!                    [--trace-out trace.jsonl [--trace-every 1000]]  (training-dynamics JSONL)
//!                    [--profile-out profile.json]  (Chrome trace for Perfetto / chrome://tracing)
//! streamsvm train    --data train.libsvm --dim 784 [--workers 4] [--chunk-kb 256]
//!                    [--test test.libsvm] [--variant ...] [--out model.meb]
//!                    (parallel byte-chunk ingest straight off disk; no registry)
//! streamsvm serve    --dataset mnist01 [--variant ball|lookahead|kernelized|ellipsoid|multiball]
//!                    [--addr 127.0.0.1:7878] [--threads 8] [--queue 64]
//!                    [--train-queue 1024] [--republish-every 32] [--snapshot live.meb]
//!                    [--train-stream data.libsvm]  (background-train from a local file)
//!                    [--hash-dim 4096 [--hash-seed 24301]]  (hash wire payloads on ingest)
//!                    [--trace-slow-us 10000]  (tail-sample slower requests into /debug/trace)
//! streamsvm loadgen  --addr 127.0.0.1:7878 [--dataset mnist01] [--qps 500] [--requests 2000]
//!                    [--threads 4] [--train-share 0.1] [--out BENCH_serve.json]
//! streamsvm snapshot --dataset synthA [--at 5000] [--variant ...] --out model.meb
//! streamsvm resume   --from model.meb --dataset synthA [--variant ...] [--out model2.meb]
//!                    (--variant asserts the sketch's recorded variant; resume always
//!                     replays with the algorithm the provenance names)
//! streamsvm merge    --inputs a.meb,b.meb,... --out merged.meb [--dataset synthA]
//!                    [--variant ...]  (asserts every input's recorded variant)
//! streamsvm table1   [--frac 1.0] [--runs 20]
//! streamsvm fig2     [--dataset mnist89] [--max-passes 512] [--frac 1.0]
//! streamsvm fig3     [--dataset mnist89] [--perms 100] [--frac 1.0]
//! streamsvm bounds   [--n 2001] [--trials 50]
//! streamsvm gen-data --dataset synthA --out dir/
//! streamsvm metrics-check --file metrics.txt [--sum pallas_requests_total]
//! streamsvm profile  [--rows 20000] [--dim 16384] [--nnz 16] [--hash-dim 4096] [--seed 42]
//!                    [--lookahead 32] [--out BENCH_obs.json] [--prom-out bench_obs.prom]
//!                    [--profile-out profile.json]  (Chrome trace for Perfetto)
//!                    [--baseline benches/baselines/BENCH_obs.json
//!                     [--warn-frac 0.5] [--fail-frac 0.8]]
//! streamsvm bench-diff --file BENCH_x.json --baseline benches/baselines/BENCH_x.json
//!                    --keys rows_per_s,variants.streamsvm [--warn-frac 0.5] [--fail-frac 0.8]
//! streamsvm fuzz     [--target http|json|codec|invariants|all] [--cases 500] [--seed 1]
//!                    [--persist-dir fuzz/failures]  (failing cases are minimized, persisted
//!                     under <dir>/<target>/, and replayed first on the next run)
//! streamsvm artifacts
//! ```
//!
//! Diagnostics go to stderr through the [`streamsvm::obs`] recorder
//! (`PALLAS_LOG=off|error|warn|info|debug|trace`); primary results stay
//! on stdout so scripts can keep grepping them.

use std::borrow::Cow;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::time::Duration;

use streamsvm::cli::Args;
use streamsvm::coordinator::parallel::{ingest_file, IngestConfig};
use streamsvm::coordinator::pipeline::{train_stream_ckpt, ExecMode, PipelineConfig};
use streamsvm::coordinator::sharded::train_sharded_variant;
use streamsvm::coordinator::stream::VecStream;
use streamsvm::data::chunked::DEFAULT_CHUNK_BYTES;
use streamsvm::data::hashing::{FeatureHasher, HashedStream};
use streamsvm::data::registry::{load_dataset, load_dataset_sized};
use streamsvm::data::Example;
use streamsvm::error::{Error, Result};
use streamsvm::eval::accuracy;
use streamsvm::exp::{bounds, fig2, fig3, table1, ExpScale};
use streamsvm::obs::trace::{TracedStream, TraceWriter};
use streamsvm::runtime::Runtime;
use streamsvm::server::{run_loadgen, serve, LoadgenConfig, ServerConfig};
use streamsvm::sketch::checkpoint::{
    read_sketch_with_fallback, resume_learner, CheckpointConfig, Checkpointer,
};
use streamsvm::sketch::codec::MebSketch;
use streamsvm::sketch::merge::merge_sketches;
use streamsvm::svm::learner::{AnyLearner, Variant};
use streamsvm::svm::{HashSpec, SlackMode, TrainOptions};

/// Default hash seed (spells "seed"); override with `--hash-seed`.
const DEFAULT_HASH_SEED: u64 = 0x5EED;

/// Parse the `--hash-dim`/`--hash-seed` pair into a [`HashSpec`].
fn parse_hash(args: &Args) -> Result<Option<HashSpec>> {
    if !args.has("hash-dim") {
        if args.has("hash-seed") {
            return Err(Error::config("--hash-seed needs --hash-dim"));
        }
        return Ok(None);
    }
    let dim: usize = args.get("hash-dim", 4096usize)?;
    if dim == 0 {
        return Err(Error::config("--hash-dim must be >= 1"));
    }
    Ok(Some(HashSpec { dim, seed: args.get("hash-seed", DEFAULT_HASH_SEED)? }))
}

/// The split to evaluate on: hashed into dim-`D` when a hash space is
/// configured (the model lives there; raw test rows have the wrong
/// dimension), borrowed as-is otherwise.
fn eval_split(hash: Option<HashSpec>, test: &[Example]) -> Cow<'_, [Example]> {
    match hash {
        Some(spec) => {
            let h = FeatureHasher::from_spec(spec);
            Cow::Owned(test.iter().map(|e| h.hash_example(e)).collect())
        }
        None => Cow::Borrowed(test),
    }
}

/// Wrap a stream in the hash-on-the-fly adapter when configured.
fn hashed_stream(
    hash: Option<HashSpec>,
    stream: VecStream,
) -> Box<dyn Iterator<Item = Example> + Send> {
    match hash {
        Some(spec) => Box::new(HashedStream::new(stream, FeatureHasher::from_spec(spec))),
        None => Box::new(stream),
    }
}

fn train_opts(args: &Args) -> Result<TrainOptions> {
    let mut o = TrainOptions::default()
        .with_c(args.get("c", 1.0)?)
        .with_lookahead(args.get("lookahead", 1usize)?)
        .with_hash(parse_hash(args)?);
    o.slack_mode = match args.str("slack", "consistent").as_str() {
        "paper" => SlackMode::Paper,
        "consistent" => SlackMode::Consistent,
        other => return Err(Error::config(format!("unknown slack mode `{other}`"))),
    };
    Ok(o)
}

fn open_runtime_opt(mode: ExecMode) -> Option<Runtime> {
    if mode == ExecMode::Pure {
        return None;
    }
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            streamsvm::obs_warn!("cli", "{e}; falling back to pure mode");
            None
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    // --data: parallel byte-chunk ingest straight off disk (no registry).
    if args.has("data") {
        return cmd_train_file(args);
    }
    let name = args.str("dataset", "synthA");
    let frac: f64 = args.get("frac", 1.0)?;
    let skipped_before = streamsvm::obs::telemetry::PARSE_SKIPPED.get();
    let mut ds = load_dataset_sized(&name, args.get("seed", 42u64)?, frac)?;
    let skipped = streamsvm::obs::telemetry::PARSE_SKIPPED.get().saturating_sub(skipped_before);
    if skipped > 0 {
        println!("data: skipped {skipped} malformed train row(s)");
    }
    if args.has("sparse") && args.get("sparse", true)? {
        ds.sparsify();
        println!(
            "sparse stream: dim={} density={:.2}% (O(nnz) updates)",
            ds.dim,
            ds.density() * 100.0
        );
    }
    let ds = ds;
    let train = train_opts(args)?;
    // C defaults per dataset unless explicitly given
    let train = if args.has("c") {
        train
    } else {
        train.with_c(table1::c_for(&name))
    };
    if let Some(spec) = train.hash {
        println!(
            "feature hashing: dim {} -> D={} (seed {:#x}); wire/stream indices unbounded",
            ds.dim, spec.dim, spec.seed
        );
    }
    // The learner's dimension is the hashed D when hashing is on.
    let dim = train.hash.map_or(ds.dim, |h| h.dim);
    let perm: i64 = args.get("perm-seed", -1i64)?;
    let stream = hashed_stream(
        train.hash,
        VecStream::of_train(&ds, (perm >= 0).then_some(perm as u64)),
    );

    // --trace-out: stream sampled training-dynamics snapshots as JSONL.
    // Telemetry feeds the trace, so the gauges/counters are turned on
    // (and zeroed) for the run.
    let trace = if args.has("trace-out") {
        let path = PathBuf::from(args.str("trace-out", "trace.jsonl"));
        let every: u64 = args.get("trace-every", 1000u64)?;
        streamsvm::obs::telemetry::reset_all();
        streamsvm::obs::set_telemetry(true);
        Some(std::sync::Arc::new(std::sync::Mutex::new(TraceWriter::create(&path, every)?)))
    } else {
        if args.has("trace-every") {
            return Err(Error::config("--trace-every needs --trace-out"));
        }
        None
    };
    let stream: Box<dyn Iterator<Item = Example> + Send> = match &trace {
        Some(w) => Box::new(TracedStream::new(stream, w.clone())),
        None => stream,
    };

    // --profile-out: record the run as a span tree and export it as
    // Chrome trace JSON on completion (load it at https://ui.perfetto.dev
    // or chrome://tracing). Worker threads attach through the profile
    // fallback, so pipeline/shard spans land on their own tracks.
    let profile_out = args
        .has("profile-out")
        .then(|| args.str("profile-out", "profile.json"));
    let profile_t0_us = streamsvm::obs::recorder::now_us();
    let ptrace = profile_out.as_ref().map(|_| {
        streamsvm::obs::set_tracing(true);
        let t = streamsvm::obs::span_tree::Trace::start(
            streamsvm::obs::span_tree::gen_trace_id(),
            streamsvm::obs::span_tree::PROFILE_SPAN_CAP,
        );
        streamsvm::obs::span_tree::set_profile_trace(Some(&t));
        t
    });

    // Validate flags up front so no combination silently ignores them.
    let variant: Variant = args.get("variant", Variant::Ball)?;
    let workers: usize = args.get("workers", 1usize)?;
    if workers == 0 {
        return Err(Error::config("--workers must be >= 1"));
    }
    let device_capable = matches!(variant, Variant::Ball | Variant::Lookahead);
    // Multiworker ingest runs each worker's sequential updater on a
    // core, so the pipeline requires ExecMode::Pure; default there.
    let default_mode = if device_capable && workers == 1 { "filter" } else { "pure" };
    let mode = match args.str("mode", default_mode).as_str() {
        "filter" => ExecMode::Filter,
        "scan" => ExecMode::Scan,
        "pure" => ExecMode::Pure,
        other => return Err(Error::config(format!("unknown mode `{other}`"))),
    };
    let ckpt_every: usize = args.get("ckpt-every", 100_000usize)?;
    if args.has("ckpt") && ckpt_every == 0 {
        return Err(Error::config("--ckpt-every must be >= 1"));
    }
    let shards: usize = args.get("shards", 1)?;
    if shards == 0 {
        return Err(Error::config("--shards must be >= 1"));
    }
    if shards > 1 && args.has("ckpt") {
        return Err(Error::config(
            "--ckpt is not supported with --shards (shard state exists only at \
             merge time; use --out to persist the merged model)",
        ));
    }
    if workers > 1 && shards > 1 {
        return Err(Error::config(
            "--workers and --shards are alternative parallel drivers; pick one",
        ));
    }
    if workers > 1 && args.has("ckpt") {
        return Err(Error::config(
            "--ckpt is not supported with --workers (worker state exists only at \
             merge time; use --out to persist the merged model)",
        ));
    }

    // ---- sharded path: S parallel one-pass learners, merge-and-reduce
    let fit_span = streamsvm::obs::span("cli", "fit");
    let (model, merges) = if shards > 1 {
        let rep =
            train_sharded_variant(stream, dim, shards, variant, train, args.get("queue", 64usize)?)?;
        let max_r = rep.shard_radii.iter().cloned().fold(0.0f64, f64::max);
        println!(
            "sharded: {} examples over {shards} shards | max shard R={max_r:.4}",
            rep.examples
        );
        println!("sharded aggregate: {}", rep.metrics.summary());
        let merges = rep.metrics.merges;
        (rep.model, merges)
    } else {
        // ---- pipeline path, with optional periodic checkpoints
        let cfg = PipelineConfig {
            train,
            mode,
            variant,
            block: None,
            queue: args.get("queue", 4usize)?,
            workers,
        };
        let mut rt = open_runtime_opt(mode);
        let cfg = if rt.is_none() && mode != ExecMode::Pure {
            PipelineConfig { mode: ExecMode::Pure, ..cfg }
        } else {
            cfg
        };
        let mut ckpt = if args.has("ckpt") {
            Some(Checkpointer::new(CheckpointConfig {
                every: ckpt_every,
                path: PathBuf::from(args.str("ckpt", "checkpoint.meb")),
                tag: name.clone(),
            }))
        } else {
            None
        };
        let report = train_stream_ckpt(rt.as_mut(), stream, dim, cfg, ckpt.as_mut())?;
        println!("pipeline: {}", report.metrics.summary());
        if let Some(ck) = &ckpt {
            println!(
                "checkpoints: {} written to {} (last at example {})",
                ck.saves(),
                ck.path().display(),
                ck.last_saved()
            );
        }
        let merges = report.metrics.merges;
        (report.model, merges)
    };
    drop(fit_span);
    let eval_span = streamsvm::obs::span("cli", "eval");
    let test = eval_split(train.hash, &ds.test);
    println!(
        "model: variant={} R={:.4} supports={} | test acc = {:.2}%",
        model.variant().name(),
        model.radius(),
        model.num_support(),
        accuracy(&model, &test) * 100.0
    );
    drop(eval_span);
    if let Some(w) = trace {
        let writer = std::sync::Arc::try_unwrap(w)
            .map_err(|_| Error::Pipeline("trace writer still shared after training".into()))?
            .into_inner()
            .unwrap_or_else(|e| e.into_inner());
        let lines = writer.lines();
        let path = writer.finish(model.radius(), merges as u64)?;
        println!("wrote trace {} ({lines} snapshots + final)", path.display());
    }
    if args.has("out") {
        let out = args.str("out", "model.meb");
        // record the Algorithm-2 merge count so a later `resume` keeps
        // reporting the paper's O(N/L) bound (0 for Algorithm 1)
        let sk = MebSketch::from_learner(&model, &name).with_merges(merges);
        sk.write_to(Path::new(&out))?;
        println!("wrote {out} ({} bytes): {}", sk.encode().len(), sk.summary());
    }
    if let (Some(path), Some(t)) = (profile_out, ptrace) {
        streamsvm::obs::span_tree::set_profile_trace(None);
        streamsvm::obs::set_tracing(false);
        let now = streamsvm::obs::recorder::now_us();
        t.finish_root("cli", "train", profile_t0_us, now.saturating_sub(profile_t0_us), vec![]);
        streamsvm::obs::chrome_trace::write_file(&t, &path)?;
        println!("wrote {path} (Chrome trace; load at https://ui.perfetto.dev)");
    }
    Ok(())
}

/// `train --data <file>`: one-pass parallel ingest straight off disk.
/// Newline-aligned byte chunks fan out to `--workers` one-pass learners
/// whose summary balls fold through the Algorithm-2 merge tree, so
/// parsing and training both scale with cores. Registry datasets,
/// hashing, and checkpointing stay on the `--dataset` path.
fn cmd_train_file(args: &Args) -> Result<()> {
    let path = PathBuf::from(args.str("data", "train.libsvm"));
    if !args.has("dim") {
        return Err(Error::config(
            "--data needs --dim D (a one-pass reader cannot pre-scan the file \
             to discover the feature dimension)",
        ));
    }
    let dim: usize = args.get("dim", 0usize)?;
    if dim == 0 {
        return Err(Error::config("--dim must be >= 1"));
    }
    if args.has("hash-dim") || args.has("hash-seed") {
        return Err(Error::config(
            "--hash-dim is not supported with --data; hashing on ingest is a \
             registry-stream feature (use --dataset, or pre-hash the file)",
        ));
    }
    if args.has("ckpt") {
        return Err(Error::config(
            "--ckpt is not supported with --data (worker state exists only at \
             merge time; use --out to persist the merged model)",
        ));
    }
    let workers: usize = args.get("workers", 1usize)?;
    if workers == 0 {
        return Err(Error::config("--workers must be >= 1"));
    }
    let chunk_kb: usize = args.get("chunk-kb", DEFAULT_CHUNK_BYTES / 1024)?;
    if chunk_kb == 0 {
        return Err(Error::config("--chunk-kb must be >= 1"));
    }
    let variant: Variant = args.get("variant", Variant::Ball)?;
    let train = train_opts(args)?;
    let fit_span = streamsvm::obs::span("cli", "fit");
    let rep = ingest_file(
        &path,
        dim,
        IngestConfig {
            train,
            variant,
            workers,
            chunk_bytes: chunk_kb * 1024,
            queue: args.get("queue", 4usize)?,
        },
    )?;
    drop(fit_span);
    println!(
        "ingest: {} rows ({} skipped) | {} chunks, {:.1} MiB | {workers} worker(s)",
        rep.rows,
        rep.skipped,
        rep.chunks,
        rep.bytes as f64 / (1024.0 * 1024.0),
    );
    println!(
        "ingest rate: {:.0} rows/s, {:.1} MB/s end to end (parse + train + merge)",
        rep.rows_per_s(),
        rep.mb_per_s()
    );
    let model = rep.model;
    print!(
        "model: variant={} R={:.4} supports={}",
        model.variant().name(),
        model.radius(),
        model.num_support()
    );
    if args.has("test") {
        let tpath = args.str("test", "test.libsvm");
        let f = std::fs::File::open(&tpath)
            .map_err(|e| Error::Io(std::io::Error::new(e.kind(), format!("{tpath}: {e}"))))?;
        let (test, test_skipped) =
            streamsvm::data::libsvm_format::read_examples_tolerant(f, Some(dim))?;
        if test_skipped > 0 {
            streamsvm::obs_warn!("cli", "{tpath}: skipped {test_skipped} malformed test row(s)");
        }
        // read_examples_tolerant grows the dimension to the max observed
        // index, so one check on any row catches an out-of-dim test file
        // before accuracy() would index past the model's weights.
        if test.first().is_some_and(|e| e.dim() > dim) {
            return Err(Error::data(format!(
                "{tpath}: test rows use feature indices beyond --dim {dim}"
            )));
        }
        print!(" | test acc = {:.2}%", accuracy(&model, &test) * 100.0);
    }
    println!();
    if args.has("out") {
        let out = args.str("out", "model.meb");
        let tag = path.file_stem().and_then(|s| s.to_str()).unwrap_or("stream");
        let sk = MebSketch::from_learner(&model, tag);
        sk.write_to(Path::new(&out))?;
        println!("wrote {out} ({} bytes): {}", sk.encode().len(), sk.summary());
    }
    Ok(())
}

/// Rebuild the training stream a sketch was produced from (same dataset
/// flags must be passed as on the original run).
fn stream_for(args: &Args, ds: &streamsvm::data::Dataset) -> Result<VecStream> {
    let perm: i64 = args.get("perm-seed", -1i64)?;
    Ok(VecStream::of_train(ds, (perm >= 0).then_some(perm as u64)))
}

fn cmd_snapshot(args: &Args) -> Result<()> {
    let name = args.str("dataset", "synthA");
    let ds = load_dataset_sized(&name, args.get("seed", 42u64)?, args.get("frac", 1.0)?)?;
    let train = train_opts(args)?;
    let train = if args.has("c") { train } else { train.with_c(table1::c_for(&name)) };
    let at: usize = args.get("at", usize::MAX)?;
    let dim = train.hash.map_or(ds.dim, |h| h.dim);
    let variant: Variant = args.get("variant", Variant::Ball)?;
    let mut model = AnyLearner::new(variant, dim, train);
    for e in hashed_stream(train.hash, stream_for(args, &ds)?).take(at) {
        model.observe_view(e.x.view(), e.y);
    }
    model.finish();
    let out = args.str("out", "model.meb");
    let sk = MebSketch::from_learner(&model, &name);
    sk.write_to(Path::new(&out))?;
    println!("wrote {out} ({} bytes): {}", sk.encode().len(), sk.summary());
    let test = eval_split(train.hash, &ds.test);
    println!("test acc = {:.2}%", accuracy(&model, &test) * 100.0);
    Ok(())
}

fn cmd_resume(args: &Args) -> Result<()> {
    let from = args.str("from", "model.meb");
    // tolerate a torn/corrupt live checkpoint by falling back to the
    // rotated `.prev` snapshot (a warning surfaces the fallback)
    let sk = read_sketch_with_fallback(Path::new(&from))?;
    println!("loaded {from}: {}", sk.summary());
    // --variant is an assertion, not a selection: resume always replays
    // with the algorithm recorded in the sketch's provenance.
    if args.has("variant") {
        let want: Variant = args.get("variant", sk.variant)?;
        if want != sk.variant {
            return Err(Error::config(format!(
                "--variant {want} disagrees with the sketch's recorded variant \
                 ({}); resume replays with the variant in provenance",
                sk.variant
            )));
        }
    }
    // Resume always uses the hash space recorded in provenance; explicit
    // flags must agree, never silently re-map the stream into a
    // different space (buckets would be unrelated coordinates).
    if args.has("hash-dim") || args.has("hash-seed") {
        let want = parse_hash(args)?;
        if want != sk.opts.hash {
            return Err(Error::config(format!(
                "--hash-dim/--hash-seed ({want:?}) disagree with the sketch's hash \
                 space ({:?}); resume uses the space recorded in provenance",
                sk.opts.hash
            )));
        }
    }
    let name = args.str("dataset", if sk.tag.is_empty() { "synthA" } else { sk.tag.as_str() });
    if name != sk.tag && !sk.tag.is_empty() {
        streamsvm::obs_warn!("cli", "sketch was trained on `{}`, resuming on `{name}`", sk.tag);
    }
    let ds = load_dataset_sized(&name, args.get("seed", 42u64)?, args.get("frac", 1.0)?)?;
    let replay = if sk.seen == 0 {
        // Empty sketch (no examples absorbed): replay the whole stream
        // with the sketch's options and variant. Ball-summary state is
        // dimension-free, so an unhashed ball/lookahead sketch adopts
        // the dataset's dimension; variant state (kernel choice,
        // ellipsoid axes, ball budget) rides along unchanged and keeps
        // the sketch's declared dimension.
        let dim_free = matches!(sk.variant, Variant::Ball | Variant::Lookahead);
        if !dim_free && sk.opts.hash.is_none() && ds.dim != sk.dim {
            return Err(Error::config(format!(
                "sketch dimension {} does not match dataset `{name}` dimension {}",
                sk.dim, ds.dim
            )));
        }
        let dim = if sk.opts.hash.is_some() || !dim_free { sk.dim } else { ds.dim };
        MebSketch::new(dim, None, 0, sk.opts, sk.tag.clone())
            .with_variant(sk.variant, sk.extra.clone())
            .with_merges(sk.merges)
    } else {
        if sk.opts.hash.is_none() && ds.dim != sk.dim {
            return Err(Error::config(format!(
                "sketch dimension {} does not match dataset `{name}` dimension {}",
                sk.dim, ds.dim
            )));
        }
        sk.clone()
    };
    let stream = hashed_stream(sk.opts.hash, stream_for(args, &ds)?);
    // Variant-generic resume: the sketch's provenance selects the
    // algorithm (ball-tagged Algorithm-2 sketches route through the
    // lookahead path so the merge count survives into `--out`).
    let model = resume_learner(&replay, stream)?;
    let merges = match &model {
        AnyLearner::Lookahead(m) => m.num_merges(),
        _ => 0,
    };
    let test = eval_split(sk.opts.hash, &ds.test);
    println!(
        "resumed {} -> {} examples | R={:.4} supports={} | test acc = {:.2}%",
        sk.seen,
        model.examples_seen(),
        model.radius(),
        model.num_support(),
        accuracy(&model, &test) * 100.0
    );
    if args.has("out") {
        let out = args.str("out", "model.meb");
        let sk2 = MebSketch::from_learner(&model, &sk.tag).with_merges(merges);
        sk2.write_to(Path::new(&out))?;
        println!("wrote {out}: {}", sk2.summary());
    }
    Ok(())
}

fn cmd_merge(args: &Args) -> Result<()> {
    let inputs = args.str("inputs", "");
    if inputs.is_empty() {
        return Err(Error::config("merge needs --inputs a.meb,b.meb,..."));
    }
    let mut sketches = Vec::new();
    for p in inputs.split(',').filter(|p| !p.is_empty()) {
        let sk = MebSketch::read_from(Path::new(p))?;
        println!("  in  {p}: {}", sk.summary());
        sketches.push(sk);
    }
    // Like resume: --variant is an assertion against provenance (the
    // pairwise same-variant gate inside merge_sketches still applies).
    if args.has("variant") {
        let want: Variant = args.get("variant", Variant::Ball)?;
        if let Some(s) = sketches.iter().find(|s| s.variant != want) {
            return Err(Error::config(format!(
                "--variant {want} disagrees with input sketch (tag={}, variant={})",
                s.tag, s.variant
            )));
        }
    }
    // Like resume: explicit hash flags must agree with provenance, never
    // be silently dropped.
    if args.has("hash-dim") || args.has("hash-seed") {
        let want = parse_hash(args)?;
        if sketches.iter().any(|s| s.opts.hash != want) {
            return Err(Error::config(format!(
                "--hash-dim/--hash-seed ({want:?}) disagree with the input sketches' \
                 hash spaces; merge uses the space recorded in provenance"
            )));
        }
    }
    let merged = merge_sketches(&sketches)?;
    println!("  out {}", merged.summary());
    let out = args.str("out", "merged.meb");
    merged.write_to(Path::new(&out))?;
    println!("wrote {out} ({} bytes)", merged.encode().len());
    if args.has("dataset") {
        let name = args.str("dataset", "synthA");
        let ds = load_dataset_sized(&name, args.get("seed", 42u64)?, args.get("frac", 1.0)?)?;
        let model = merged.to_model();
        let test = eval_split(merged.opts.hash, &ds.test);
        println!("test acc on {name} = {:.2}%", accuracy(&model, &test) * 100.0);
    }
    Ok(())
}

/// Start the network server: train an initial model on the dataset, then
/// serve `/predict`, `/predict_batch`, `/train`, `/snapshot` and `/stats`
/// until the process is killed. `--republish-every N` is the hot-swap
/// interval: the background trainer republishes the serving snapshot
/// (and rewrites `--snapshot <path>.meb`, if given) every N absorbed
/// examples across both training sources. `--train-stream <path>` feeds
/// the trainer from a local LIBSVM file, interleaved with the `/train`
/// queue; progress is live in `/stats` under `"stream"` and the
/// snapshot is rewritten once more when the file is fully consumed.
fn cmd_serve(args: &Args) -> Result<()> {
    let name = args.str("dataset", "mnist01");
    let hash = parse_hash(args)?;
    let mut ds = load_dataset_sized(&name, args.get("seed", 42u64)?, args.get("frac", 0.25)?)?;
    if let Some(spec) = hash {
        println!(
            "feature hashing on ingest: D={} (seed {:#x}); wire payloads may carry arbitrary indices",
            spec.dim, spec.seed
        );
        ds = FeatureHasher::from_spec(spec).hash_dataset(&ds);
    }
    let train = if args.has("c") {
        TrainOptions::default().with_c(args.get("c", 1.0)?)
    } else {
        TrainOptions::default().with_c(table1::c_for(&name))
    }
    .with_hash(hash);
    let variant: Variant = args.get("variant", Variant::Ball)?;
    let model = AnyLearner::fit(ds.train.iter(), variant, ds.dim, train);
    println!(
        "trained on {}: variant={} dim={} supports={} | test acc = {:.2}%",
        ds.name,
        model.variant().name(),
        ds.dim,
        model.num_support(),
        accuracy(&model, &ds.test) * 100.0
    );
    let cfg = ServerConfig {
        addr: args.str("addr", "127.0.0.1:7878"),
        threads: args.get("threads", 8usize)?,
        conn_queue: args.get("queue", 64usize)?,
        train_queue: args.get("train-queue", 1024usize)?,
        republish_every: args.get("republish-every", 32usize)?,
        snapshot: args
            .has("snapshot")
            .then(|| PathBuf::from(args.str("snapshot", "live.meb"))),
        read_timeout: Duration::from_millis(args.get("read-timeout-ms", 10_000u64)?),
        tag: name.clone(),
        hash,
        train_stream: args
            .has("train-stream")
            .then(|| PathBuf::from(args.str("train-stream", "train.libsvm"))),
        trace_slow_us: args.get("trace-slow-us", 10_000u64)?,
        ..Default::default()
    };
    if let Some(p) = &cfg.train_stream {
        println!(
            "background train stream: {} (interleaved with /train; progress in /stats)",
            p.display()
        );
    }
    let handle = serve(model, cfg)?;
    println!(
        "serving {name} on http://{}/ (predict, predict_batch, train, snapshot, stats, metrics, trace)",
        handle.addr()
    );
    handle.run_forever()
}

/// Drive a running server at a target QPS with a mixed predict/train
/// workload and write `BENCH_serve.json`.
fn cmd_loadgen(args: &Args) -> Result<()> {
    let name = args.str("dataset", "mnist01");
    let ds = load_dataset_sized(&name, args.get("seed", 42u64)?, args.get("frac", 0.25)?)?;
    let cfg = LoadgenConfig {
        addr: args.str("addr", "127.0.0.1:7878"),
        threads: args.get("threads", 4usize)?,
        requests: args.get("requests", 2000usize)?,
        qps: args.get("qps", 500.0f64)?,
        train_share: args.get("train-share", 0.1f64)?,
        read_timeout: Duration::from_millis(args.get("read-timeout-ms", 5_000u64)?),
        seed: args.get("seed", 42u64)?,
    };
    println!("loadgen → {} ({} requests, target {} rps)", cfg.addr, cfg.requests, cfg.qps);
    let report = run_loadgen(&cfg, &ds.test)?;
    println!("{}", report.summary());
    let out = args.str("out", "BENCH_serve.json");
    report.write_json(Path::new(&out))?;
    println!("wrote {out}");
    if report.ok == 0 {
        return Err(Error::Pipeline(format!(
            "no successful round-trips against {} ({} errors)",
            cfg.addr, report.errors
        )));
    }
    Ok(())
}

/// Dot-path keys gated by `profile --baseline` (all higher-is-better).
const PROFILE_GATE_KEYS: [&str; 6] = [
    "rows_per_s",
    "variants.streamsvm",
    "variants.lookahead",
    "variants.kernelized",
    "variants.ellipsoid",
    "variants.multiball",
];

/// Shared gate driver for `profile --baseline` and `bench-diff`:
/// regressions inside the warn band print warnings and exit 0; past the
/// fail band the command errors, which is what fails the CI job.
fn gate_and_report(current: &str, baseline: &str, keys: &[&str], args: &Args) -> Result<()> {
    use streamsvm::obs::profiler::{gate_against, Gate};
    let warn_frac: f64 = args.get("warn-frac", 0.5)?;
    let fail_frac: f64 = args.get("fail-frac", 0.8)?;
    match gate_against(current, baseline, keys, warn_frac, fail_frac).map_err(Error::Pipeline)? {
        Gate::Ok => {
            println!(
                "baseline gate: ok ({} keys within {:.0}% of baseline)",
                keys.len(),
                warn_frac * 100.0
            );
        }
        Gate::Warn(w) => {
            for (k, cur, base) in &w {
                streamsvm::obs_warn!("cli", "{k} regressed: {cur:.1} vs baseline {base:.1}");
            }
            println!("baseline gate: WARN on {} key(s) (inside the fail tolerance)", w.len());
        }
        Gate::Fail(f) => {
            for (k, cur, base) in &f {
                eprintln!(
                    "FAIL {k}: {cur:.1} vs baseline {base:.1} (> {:.0}% regression)",
                    fail_frac * 100.0
                );
            }
            return Err(Error::Pipeline(format!(
                "{} key(s) regressed past the fail tolerance",
                f.len()
            )));
        }
    }
    Ok(())
}

/// Run the standardized self-profiling workload, write `BENCH_obs.json`
/// (plus optional Prometheus exposition and Chrome trace), and gate the
/// numbers against a committed baseline when one is given.
fn cmd_profile(args: &Args) -> Result<()> {
    use streamsvm::obs::profiler::{self, ProfileConfig};
    let d = ProfileConfig::default();
    let cfg = ProfileConfig {
        rows: args.get("rows", d.rows)?,
        dim: args.get("dim", d.dim)?,
        nnz: args.get("nnz", d.nnz)?,
        hash_dim: args.get("hash-dim", d.hash_dim)?,
        seed: args.get("seed", d.seed)?,
        lookahead: args.get("lookahead", d.lookahead)?,
        republish_every: d.republish_every,
    };
    // The whole run records as a span tree so --profile-out renders the
    // same timeline the phase table summarizes.
    streamsvm::obs::set_tracing(true);
    let t0_us = streamsvm::obs::recorder::now_us();
    let trace = streamsvm::obs::span_tree::Trace::start(
        streamsvm::obs::span_tree::gen_trace_id(),
        streamsvm::obs::span_tree::PROFILE_SPAN_CAP,
    );
    streamsvm::obs::span_tree::set_profile_trace(Some(&trace));
    let report = profiler::run_profile(&cfg);
    streamsvm::obs::span_tree::set_profile_trace(None);
    streamsvm::obs::set_tracing(false);
    let now = streamsvm::obs::recorder::now_us();
    trace.finish_root("profile", "run", t0_us, now.saturating_sub(t0_us), vec![]);

    let total_s = report.total.as_secs_f64();
    println!(
        "profile: {} rows in {total_s:.3}s ({:.0} rows/s; phases cover {:.1}% of wall)",
        cfg.rows,
        report.rows_per_s,
        100.0 * report.phases.sum().as_secs_f64() / total_s.max(1e-9)
    );
    for name in profiler::PHASES {
        println!("  phase   {name:<10} {:>9.4}s", report.phases.get(name).as_secs_f64());
    }
    for &(name, rps) in &report.variants {
        println!("  variant {name:<10} {rps:>9.0} rows/s");
    }
    let out = args.str("out", "BENCH_obs.json");
    std::fs::write(&out, report.to_json())?;
    println!("wrote {out}");
    if args.has("prom-out") {
        let p = args.str("prom-out", "bench_obs.prom");
        std::fs::write(&p, report.to_prom())?;
        println!("wrote {p}");
    }
    if args.has("profile-out") {
        let p = args.str("profile-out", "profile.json");
        streamsvm::obs::chrome_trace::write_file(&trace, &p)?;
        println!("wrote {p} (Chrome trace; load at https://ui.perfetto.dev)");
    }
    if args.has("baseline") {
        let path = args.str("baseline", "benches/baselines/BENCH_obs.json");
        let baseline = std::fs::read_to_string(&path)?;
        println!("gating against {path}");
        gate_and_report(&report.to_json(), &baseline, &PROFILE_GATE_KEYS, args)?;
    }
    Ok(())
}

/// Compare a freshly produced benchmark JSON against its committed
/// baseline with the same warn-then-fail tolerance the profile gate
/// uses. `--keys` are comma-separated dot-paths present in both files.
fn cmd_bench_diff(args: &Args) -> Result<()> {
    let file = args.str("file", "BENCH_obs.json");
    let baseline = args.str("baseline", "");
    if baseline.is_empty() {
        return Err(Error::config("bench-diff needs --baseline <committed json>"));
    }
    let keys_arg = args.str("keys", "");
    if keys_arg.is_empty() {
        return Err(Error::config("bench-diff needs --keys k1,k2,... (dot-paths)"));
    }
    let current = std::fs::read_to_string(&file)?;
    let base = std::fs::read_to_string(&baseline)?;
    let keys: Vec<&str> = keys_arg.split(',').filter(|k| !k.is_empty()).collect();
    println!("bench-diff: {file} vs {baseline} ({} key(s))", keys.len());
    gate_and_report(&current, &base, &keys, args)
}

fn cmd_fuzz(args: &Args) -> Result<()> {
    use streamsvm::fuzz::{FuzzConfig, Target};

    let which = args.str("target", "all");
    let targets: Vec<Target> = if which == "all" {
        Target::ALL.to_vec()
    } else {
        vec![which.parse()?]
    };
    let cfg = FuzzConfig {
        cases: args.get("cases", 500)?,
        seed: args.get("seed", 1)?,
        persist_dir: Some(PathBuf::from(args.str("persist-dir", "fuzz/failures"))),
    };
    let mut dirty = Vec::new();
    for t in targets {
        let report = streamsvm::fuzz::run(t, &cfg)?;
        println!(
            "fuzz {:<10} replayed {} ({} still failing), executed {}, failed {}, persisted {}",
            report.target,
            report.replayed,
            report.replay_failures.len(),
            report.executed,
            report.failures,
            report.persisted.len()
        );
        for p in report.replay_failures.iter().chain(report.persisted.iter()) {
            println!("  failing case: {}", p.display());
        }
        if let Some(msg) = &report.sample_failure {
            println!("  first failure: {msg}");
        }
        if !report.clean() {
            dirty.push(report.target);
        }
    }
    if dirty.is_empty() {
        Ok(())
    } else {
        Err(Error::Pipeline(format!(
            "fuzz found failing cases in: {} (cases persisted for replay; \
             re-run with the same --persist-dir after fixing)",
            dirty.join(", ")
        )))
    }
}

fn scale_from(args: &Args) -> Result<ExpScale> {
    Ok(ExpScale {
        train_frac: args.get("frac", 1.0)?,
        runs: args.get("runs", 20)?,
        seed: args.get("seed", 42)?,
    })
}

fn main() -> Result<()> {
    streamsvm::obs::init_cli();
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "train" => cmd_train(&args)?,
        "serve" => cmd_serve(&args)?,
        "loadgen" => cmd_loadgen(&args)?,
        "snapshot" => cmd_snapshot(&args)?,
        "resume" => cmd_resume(&args)?,
        "merge" => cmd_merge(&args)?,
        "profile" => cmd_profile(&args)?,
        "fuzz" => cmd_fuzz(&args)?,
        "bench-diff" => cmd_bench_diff(&args)?,
        "table1" => {
            let rows = table1::run(&scale_from(&args)?)?;
            table1::print(&rows);
        }
        "fig2" => {
            let f = fig2::run(
                &args.str("dataset", "mnist89"),
                args.get("max-passes", 512)?,
                &scale_from(&args)?,
            )?;
            fig2::print(&f);
        }
        "fig3" => {
            let mut scale = scale_from(&args)?;
            scale.runs = 1;
            let pts = fig3::run(
                &args.str("dataset", "mnist89"),
                &fig3::DEFAULT_LS,
                args.get("perms", 100)?,
                &scale,
            )?;
            fig3::print(&pts);
        }
        "bounds" => {
            let pts = bounds::run(
                args.get("n", 2001)?,
                &[1, 2, 5, 10, 50],
                args.get("trials", 50)?,
                args.get("seed", 42)?,
            );
            bounds::print(&pts);
        }
        "gen-data" => {
            let name = args.str("dataset", "synthA");
            let out = args.str("out", ".");
            let ds = load_dataset(&name, args.get("seed", 42)?)?;
            std::fs::create_dir_all(&out)?;
            for (split, exs) in [("train", &ds.train), ("test", &ds.test)] {
                let path = format!("{out}/{name}.{split}.libsvm");
                let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
                for e in exs {
                    write!(f, "{}", if e.y > 0.0 { "+1" } else { "-1" })?;
                    for (i, v) in e.x.iter_nonzero() {
                        write!(f, " {}:{}", i + 1, v)?;
                    }
                    writeln!(f)?;
                }
                println!("wrote {path} ({} examples)", exs.len());
            }
        }
        "metrics-check" => {
            // CI helper: validate a scraped /metrics body against the
            // strict exposition grammar, or sum one metric family.
            let path = args.str("file", "metrics.txt");
            let body = std::fs::read_to_string(&path)?;
            if args.has("sum") {
                let metric = args.str("sum", "");
                match streamsvm::obs::prom::sum_metric(&body, &metric) {
                    Some(v) => println!("{v}"),
                    None => {
                        return Err(Error::config(format!(
                            "metric `{metric}` not found in {path}"
                        )))
                    }
                }
            } else {
                let fams = streamsvm::obs::prom::check_exposition(&body)
                    .map_err(|e| Error::Pipeline(format!("{path}: {e}")))?;
                println!("{path}: valid Prometheus exposition ({fams} families)");
            }
        }
        "artifacts" => match Runtime::open_default() {
            Ok(rt) => {
                println!("artifact dir: {}", rt.artifact_dir().display());
                for (e, b, d) in rt.available() {
                    println!("  {e:<10} b={b:<4} d={d}");
                }
            }
            Err(e) => println!("{e}"),
        },
        _ => {
            println!("streamsvm — one-pass streaming l2-SVM (IJCAI'09 reproduction)");
            println!(
                "commands: train serve loadgen snapshot resume merge table1 fig2 \
                 fig3 bounds gen-data metrics-check profile bench-diff fuzz artifacts"
            );
            println!("see README.md for flags (--key value and --key=value)");
        }
    }
    Ok(())
}
