//! StreamSVM CLI — the leader entrypoint.
//!
//! ```text
//! streamsvm train    --dataset mnist89 [--lookahead 10] [--c 10] [--mode filter|scan|pure]
//! streamsvm serve    --dataset mnist01 [--requests 5000] [--batch 64]
//! streamsvm table1   [--frac 1.0] [--runs 20]
//! streamsvm fig2     [--dataset mnist89] [--max-passes 512] [--frac 1.0]
//! streamsvm fig3     [--dataset mnist89] [--perms 100] [--frac 1.0]
//! streamsvm bounds   [--n 2001] [--trials 50]
//! streamsvm gen-data --dataset synthA --out dir/
//! streamsvm artifacts
//! ```

use std::io::Write as _;

use streamsvm::cli::Args;
use streamsvm::coordinator::pipeline::{train_stream, ExecMode, PipelineConfig};
use streamsvm::coordinator::service::{PredictService, ServiceConfig};
use streamsvm::coordinator::stream::VecStream;
use streamsvm::data::registry::{load_dataset, load_dataset_sized};
use streamsvm::error::{Error, Result};
use streamsvm::eval::accuracy;
use streamsvm::exp::{bounds, fig2, fig3, table1, ExpScale};
use streamsvm::runtime::Runtime;
use streamsvm::svm::{SlackMode, TrainOptions};

fn train_opts(args: &Args) -> Result<TrainOptions> {
    let mut o = TrainOptions::default()
        .with_c(args.get("c", 1.0)?)
        .with_lookahead(args.get("lookahead", 1usize)?);
    o.slack_mode = match args.str("slack", "consistent").as_str() {
        "paper" => SlackMode::Paper,
        "consistent" => SlackMode::Consistent,
        other => return Err(Error::config(format!("unknown slack mode `{other}`"))),
    };
    Ok(o)
}

fn open_runtime_opt(mode: ExecMode) -> Option<Runtime> {
    if mode == ExecMode::Pure {
        return None;
    }
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("warning: {e}; falling back to pure mode");
            None
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let name = args.str("dataset", "synthA");
    let frac: f64 = args.get("frac", 1.0)?;
    let ds = load_dataset_sized(&name, args.get("seed", 42u64)?, frac)?;
    let mode = match args.str("mode", "filter").as_str() {
        "filter" => ExecMode::Filter,
        "scan" => ExecMode::Scan,
        "pure" => ExecMode::Pure,
        other => return Err(Error::config(format!("unknown mode `{other}`"))),
    };
    let train = train_opts(args)?;
    // C defaults per dataset unless explicitly given
    let train = if args.has("c") {
        train
    } else {
        train.with_c(table1::c_for(&name))
    };
    let cfg = PipelineConfig { train, mode, block: None, queue: args.get("queue", 4usize)? };
    let mut rt = open_runtime_opt(mode);
    let cfg = if rt.is_none() && mode != ExecMode::Pure {
        PipelineConfig { mode: ExecMode::Pure, ..cfg }
    } else {
        cfg
    };
    let perm: i64 = args.get("perm-seed", -1i64)?;
    let stream = VecStream::of_train(&ds, (perm >= 0).then_some(perm as u64));
    let report = train_stream(rt.as_mut(), stream, ds.dim, cfg)?;
    println!("pipeline: {}", report.metrics.summary());
    println!(
        "model: R={:.4} supports={} | test acc = {:.2}%",
        report.model.radius(),
        report.model.num_support(),
        accuracy(&report.model, &ds.test) * 100.0
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let name = args.str("dataset", "mnist01");
    let ds = load_dataset_sized(&name, 42, args.get("frac", 0.25)?)?;
    let train = TrainOptions::default().with_c(table1::c_for(&name));
    let model = streamsvm::svm::streamsvm::StreamSvm::fit(ds.train.iter(), ds.dim, &train);
    println!("trained on {}: {} supports", ds.name, model.num_support());
    let n_req: usize = args.get("requests", 5000)?;
    let batch: usize = args.get("batch", 64)?;
    let svc = PredictService::new(
        model.weights().to_vec(),
        ServiceConfig { batch, ..Default::default() },
    );
    let client = svc.client();
    let test = std::sync::Arc::new(ds.test.clone());
    let workers: Vec<_> = (0..4)
        .map(|k| {
            let c = client.clone();
            let test = test.clone();
            std::thread::spawn(move || {
                let mut correct = 0usize;
                let mut total = 0usize;
                for i in 0..n_req / 4 {
                    let e = &test[(k * 31 + i * 7) % test.len()];
                    let s = c.score(e.x.clone()).unwrap();
                    total += 1;
                    if (s >= 0.0) == (e.y > 0.0) {
                        correct += 1;
                    }
                }
                (correct, total)
            })
        })
        .collect();
    drop(client);
    let mut rt = open_runtime_opt(ExecMode::Filter);
    let stats = svc.run(rt.as_mut())?;
    let (mut correct, mut total) = (0, 0);
    for w in workers {
        let (c, t) = w.join().unwrap();
        correct += c;
        total += t;
    }
    println!(
        "served {} requests in {} batches (mean fill {:.1})",
        stats.requests,
        stats.batches,
        stats.mean_batch_fill()
    );
    println!("latency: {}", stats.latency.summary());
    println!("serving accuracy: {:.2}%", correct as f64 / total as f64 * 100.0);
    Ok(())
}

fn scale_from(args: &Args) -> Result<ExpScale> {
    Ok(ExpScale {
        train_frac: args.get("frac", 1.0)?,
        runs: args.get("runs", 20)?,
        seed: args.get("seed", 42)?,
    })
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "train" => cmd_train(&args)?,
        "serve" => cmd_serve(&args)?,
        "table1" => {
            let rows = table1::run(&scale_from(&args)?)?;
            table1::print(&rows);
        }
        "fig2" => {
            let f = fig2::run(
                &args.str("dataset", "mnist89"),
                args.get("max-passes", 512)?,
                &scale_from(&args)?,
            )?;
            fig2::print(&f);
        }
        "fig3" => {
            let mut scale = scale_from(&args)?;
            scale.runs = 1;
            let pts = fig3::run(
                &args.str("dataset", "mnist89"),
                &fig3::DEFAULT_LS,
                args.get("perms", 100)?,
                &scale,
            )?;
            fig3::print(&pts);
        }
        "bounds" => {
            let pts = bounds::run(
                args.get("n", 2001)?,
                &[1, 2, 5, 10, 50],
                args.get("trials", 50)?,
                args.get("seed", 42)?,
            );
            bounds::print(&pts);
        }
        "gen-data" => {
            let name = args.str("dataset", "synthA");
            let out = args.str("out", ".");
            let ds = load_dataset(&name, args.get("seed", 42)?)?;
            std::fs::create_dir_all(&out)?;
            for (split, exs) in [("train", &ds.train), ("test", &ds.test)] {
                let path = format!("{out}/{name}.{split}.libsvm");
                let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
                for e in exs {
                    write!(f, "{}", if e.y > 0.0 { "+1" } else { "-1" })?;
                    for (i, &v) in e.x.iter().enumerate() {
                        if v != 0.0 {
                            write!(f, " {}:{}", i + 1, v)?;
                        }
                    }
                    writeln!(f)?;
                }
                println!("wrote {path} ({} examples)", exs.len());
            }
        }
        "artifacts" => match Runtime::open_default() {
            Ok(rt) => {
                println!("artifact dir: {}", rt.artifact_dir().display());
                for (e, b, d) in rt.available() {
                    println!("  {e:<10} b={b:<4} d={d}");
                }
            }
            Err(e) => println!("{e}"),
        },
        "help" | _ => {
            println!("streamsvm — one-pass streaming l2-SVM (IJCAI'09 reproduction)");
            println!("commands: train serve table1 fig2 fig3 bounds gen-data artifacts");
            println!("see README.md for flags");
        }
    }
    Ok(())
}
