//! Evaluation: classifier trait, accuracy/confusion metrics, and
//! mean/std aggregation used by all experiment harnesses.

use crate::data::{Example, FeaturesView};

/// Anything that scores an example (sign of the score = predicted label).
pub trait Classifier {
    /// Raw margin; the predicted label is `score(x).signum()`.
    fn score(&self, x: &[f32]) -> f64;

    /// [`Self::score`] for a dense-or-sparse feature view. The default
    /// densifies sparse views; models with a dense weight vector should
    /// override with an O(nnz) dot (as [`crate::svm::streamsvm::StreamSvm`]
    /// does).
    fn score_view(&self, x: FeaturesView<'_>) -> f64 {
        match x {
            FeaturesView::Dense(d) => self.score(d),
            sparse => self.score(&sparse.to_dense()),
        }
    }

    fn predict(&self, x: &[f32]) -> f32 {
        if self.score(x) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    fn predict_view(&self, x: FeaturesView<'_>) -> f32 {
        if self.score_view(x) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }
}

/// Accuracy of `model` over a slice of examples.
pub fn accuracy<M: Classifier + ?Sized>(model: &M, examples: &[Example]) -> f64 {
    if examples.is_empty() {
        return 0.0;
    }
    let ok = examples
        .iter()
        .filter(|e| model.predict_view(e.x.view()) == e.y)
        .count();
    ok as f64 / examples.len() as f64
}

/// 2×2 confusion counts.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Confusion {
    pub tp: usize,
    pub tn: usize,
    pub fp: usize,
    pub fneg: usize,
}

impl Confusion {
    pub fn of<M: Classifier + ?Sized>(model: &M, examples: &[Example]) -> Self {
        let mut c = Confusion::default();
        for e in examples {
            match (model.predict_view(e.x.view()) > 0.0, e.y > 0.0) {
                (true, true) => c.tp += 1,
                (false, false) => c.tn += 1,
                (true, false) => c.fp += 1,
                (false, true) => c.fneg += 1,
            }
        }
        c
    }

    pub fn accuracy(&self) -> f64 {
        let n = self.tp + self.tn + self.fp + self.fneg;
        if n == 0 {
            0.0
        } else {
            (self.tp + self.tn) as f64 / n as f64
        }
    }

    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 {
            0.0
        } else {
            self.tp as f64 / (self.tp + self.fp) as f64
        }
    }

    pub fn recall(&self) -> f64 {
        if self.tp + self.fneg == 0 {
            0.0
        } else {
            self.tp as f64 / (self.tp + self.fneg) as f64
        }
    }
}

/// Mean and (population) standard deviation of a sample.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Fixed(f64);
    impl Classifier for Fixed {
        fn score(&self, _x: &[f32]) -> f64 {
            self.0
        }
    }

    struct FirstCoord;
    impl Classifier for FirstCoord {
        fn score(&self, x: &[f32]) -> f64 {
            x[0] as f64
        }
    }

    fn exs() -> Vec<Example> {
        vec![
            Example::new(vec![1.0], 1.0),
            Example::new(vec![-1.0], -1.0),
            Example::new(vec![2.0], -1.0),
            Example::new(vec![-2.0], 1.0),
        ]
    }

    #[test]
    fn accuracy_counts() {
        assert_eq!(accuracy(&FirstCoord, &exs()), 0.5);
        assert_eq!(accuracy(&Fixed(1.0), &exs()), 0.5);
        assert_eq!(accuracy(&Fixed(1.0), &[]), 0.0);
    }

    #[test]
    fn confusion_counts() {
        let c = Confusion::of(&FirstCoord, &exs());
        assert_eq!(c, Confusion { tp: 1, tn: 1, fp: 1, fneg: 1 });
        assert_eq!(c.accuracy(), 0.5);
        assert_eq!(c.precision(), 0.5);
        assert_eq!(c.recall(), 0.5);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }
}
