//! Crate-wide error type.
//!
//! Hand-written `Display`/`Error` impls (no `thiserror` in the offline
//! image); the PJRT variant only exists when the `pjrt` feature is on.

/// Unified error for the StreamSVM crate.
#[derive(Debug)]
pub enum Error {
    /// Errors bubbling up from the PJRT runtime (`xla` crate).
    #[cfg(feature = "pjrt")]
    Xla(xla::Error),

    /// I/O (artifact files, dataset files, sketch files).
    Io(std::io::Error),

    /// Artifact registry problems: missing manifest entries, shape
    /// mismatches between the requested block and the compiled bucket.
    Artifact(String),

    /// Malformed dataset input (LIBSVM parser, registry names).
    Data(String),

    /// Invalid configuration (CLI, TrainOptions).
    Config(String),

    /// A pipeline stage disappeared (channel closed unexpectedly).
    Pipeline(String),

    /// Malformed or incompatible MEB sketch (codec, merge, checkpoint).
    Sketch(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => write!(f, "xla runtime: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::Data(m) => write!(f, "data: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Pipeline(m) => write!(f, "pipeline: {m}"),
            Error::Sketch(m) => write!(f, "sketch: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            #[cfg(feature = "pjrt")]
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl Error {
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    pub fn data(msg: impl Into<String>) -> Self {
        Error::Data(msg.into())
    }
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn sketch(msg: impl Into<String>) -> Self {
        Error::Sketch(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(Error::artifact("x").to_string(), "artifact: x");
        assert_eq!(Error::data("x").to_string(), "data: x");
        assert_eq!(Error::config("x").to_string(), "config: x");
        assert_eq!(Error::Pipeline("x".into()).to_string(), "pipeline: x");
        assert_eq!(Error::sketch("x").to_string(), "sketch: x");
    }

    #[test]
    fn io_preserves_source() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
