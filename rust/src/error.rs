//! Crate-wide error type.

/// Unified error for the StreamSVM crate.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Errors bubbling up from the PJRT runtime (`xla` crate).
    #[error("xla runtime: {0}")]
    Xla(#[from] xla::Error),

    /// I/O (artifact files, dataset files).
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Artifact registry problems: missing manifest entries, shape
    /// mismatches between the requested block and the compiled bucket.
    #[error("artifact: {0}")]
    Artifact(String),

    /// Malformed dataset input (LIBSVM parser, registry names).
    #[error("data: {0}")]
    Data(String),

    /// Invalid configuration (CLI, TrainOptions).
    #[error("config: {0}")]
    Config(String),

    /// A pipeline stage disappeared (channel closed unexpectedly).
    #[error("pipeline: {0}")]
    Pipeline(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    pub fn data(msg: impl Into<String>) -> Self {
        Error::Data(msg.into())
    }
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
}
