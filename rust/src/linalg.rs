//! Dense and sparse f32 vector kernels for the Rust-side hot paths.
//!
//! The per-example StreamSVM update is O(D) vector work on dense rows.
//! The dense reductions (`dot`/`norm2`/`sqdist_scaled` and their
//! `_scaled` metric variants) run as explicit 8-lane chunked loops:
//! eight independent f64 accumulators fed from `chunks_exact(8)` blocks
//! (no cross-lane dependency, so LLVM turns the inner loop into vector
//! FMAs), folded in the **pinned pairwise order**
//! `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`, with the `< 8` remainder
//! accumulated sequentially on top. The order is part of the contract —
//! results are bit-reproducible run to run and machine to machine, and
//! for `n < 8` the lanes are all zero so the result is bit-identical to
//! the plain sequential loop. `dot` and `dot_scaled` share the exact
//! same lane structure, which is what keeps `dot_scaled` at a unit
//! metric bit-identical to `dot` (multiplying by exactly 1.0 is exact
//! per lane) — the isotropic-ellipsoid ≡ `BallState` conformance anchor.
//!
//! The elementwise updates (`blend_into`/`axpy`/`scale`) are chunked the
//! same way for the vectorizer; having no accumulator, their results
//! are bit-identical to the sequential loop at every length.
//!
//! The `sparse_*` variants take parallel `idx`/`val` arrays (0-based,
//! strictly increasing indices) and cost O(nnz), which is what makes
//! the sparse LIBSVM hot path scale with the number of stored
//! coordinates instead of the ambient dimension; their gather patterns
//! don't vectorize profitably, so they stay sequential.

/// Lane width of the chunked dense reductions.
const LANES: usize = 8;

/// The pinned lane fold: a balanced pairwise tree, NOT a left fold.
/// Changing this changes every dense reduction's low bits — it is part
/// of the bit-reproducibility contract.
#[inline]
fn reduce8(l: &[f64; LANES]) -> f64 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// Dot product `<a, b>` in f64 accumulation (streamed sums over hundreds of
/// f32 terms lose precision fast in f32; the ball geometry is sensitive
/// near `d ≈ R`). 8-lane chunked; bit-identical to the sequential loop
/// for `n < 8`.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f64; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for l in 0..LANES {
            lanes[l] += xa[l] as f64 * xb[l] as f64;
        }
    }
    let mut acc = reduce8(&lanes);
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        acc += *x as f64 * *y as f64;
    }
    acc
}

/// Squared Euclidean norm.
#[inline]
pub fn norm2(a: &[f32]) -> f64 {
    dot(a, a)
}

/// `||w - y x||^2` without materializing the difference (the inner loop of
/// Algorithm 1, line 5). 8-lane chunked like [`dot`].
#[inline]
pub fn sqdist_scaled(w: &[f32], x: &[f32], y: f32) -> f64 {
    assert_eq!(w.len(), x.len());
    let y = y as f64;
    let mut lanes = [0.0f64; LANES];
    let mut cw = w.chunks_exact(LANES);
    let mut cx = x.chunks_exact(LANES);
    for (a, b) in cw.by_ref().zip(cx.by_ref()) {
        for l in 0..LANES {
            let d = a[l] as f64 - y * b[l] as f64;
            lanes[l] += d * d;
        }
    }
    let mut acc = reduce8(&lanes);
    for (a, b) in cw.remainder().iter().zip(cx.remainder()) {
        let d = *a as f64 - y * *b as f64;
        acc += d * d;
    }
    acc
}

/// `w += beta * (y x - w)`, i.e. `w = (1-beta) w + beta y x` (Algorithm 1,
/// line 7). Elementwise: chunking changes nothing but the codegen.
#[inline]
pub fn blend_into(w: &mut [f32], x: &[f32], y: f32, beta: f32) {
    assert_eq!(w.len(), x.len());
    let omb = 1.0 - beta;
    let by = beta * y;
    let mut cw = w.chunks_exact_mut(LANES);
    let mut cx = x.chunks_exact(LANES);
    for (a, b) in cw.by_ref().zip(cx.by_ref()) {
        for l in 0..LANES {
            a[l] = omb * a[l] + by * b[l];
        }
    }
    for (a, b) in cw.into_remainder().iter_mut().zip(cx.remainder()) {
        *a = omb * *a + by * *b;
    }
}

/// `a += s * b`. Elementwise, chunked for the vectorizer.
#[inline]
pub fn axpy(a: &mut [f32], s: f32, b: &[f32]) {
    assert_eq!(a.len(), b.len());
    let mut ca = a.chunks_exact_mut(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for l in 0..LANES {
            xa[l] += s * xb[l];
        }
    }
    for (x, y) in ca.into_remainder().iter_mut().zip(cb.remainder()) {
        *x += s * *y;
    }
}

/// `a *= s`.
#[inline]
pub fn scale(a: &mut [f32], s: f32) {
    let mut ca = a.chunks_exact_mut(LANES);
    for c in ca.by_ref() {
        for v in c {
            *v *= s;
        }
    }
    for v in ca.into_remainder() {
        *v *= s;
    }
}

/// Sparse dot `<w, x>` for `x` given as `idx`/`val` pairs — O(nnz).
/// Accumulates in f64 like [`dot`]; indices must be within `w`.
#[inline]
pub fn sparse_dot(w: &[f32], idx: &[u32], val: &[f32]) -> f64 {
    assert_eq!(idx.len(), val.len());
    let mut acc = 0.0f64;
    for k in 0..idx.len() {
        acc += w[idx[k] as usize] as f64 * val[k] as f64;
    }
    acc
}

/// Sparse scatter-add `a[idx[k]] += s * val[k]` — O(nnz).
#[inline]
pub fn sparse_axpy(a: &mut [f32], s: f32, idx: &[u32], val: &[f32]) {
    assert_eq!(idx.len(), val.len());
    for k in 0..idx.len() {
        a[idx[k] as usize] += s * val[k];
    }
}

/// Sparse–sparse dot `<a, b>` for two `idx`/`val` pairs by merge-join on
/// the (strictly increasing) index arrays — O(nnz_a + nnz_b). This is
/// what makes the Algorithm-2 merge Gram O(L²·nnz) instead of O(L²·D).
#[inline]
pub fn sparse_sparse_dot(ia: &[u32], va: &[f32], ib: &[u32], vb: &[f32]) -> f64 {
    assert_eq!(ia.len(), va.len());
    assert_eq!(ib.len(), vb.len());
    let (mut i, mut j) = (0usize, 0usize);
    let mut acc = 0.0f64;
    while i < ia.len() && j < ib.len() {
        match ia[i].cmp(&ib[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                acc += va[i] as f64 * vb[j] as f64;
                i += 1;
                j += 1;
            }
        }
    }
    acc
}

/// `||w - y x||²` for sparse `x`, given the cached `||w||²` — O(nnz) via
/// the expansion `||w||² − 2y⟨w,x⟩ + ||x||²` (clamped at 0 against
/// cancellation in the nearly-coincident case).
#[inline]
pub fn sparse_sqdist_scaled(w: &[f32], wnorm2: f64, idx: &[u32], val: &[f32], y: f32) -> f64 {
    let wx = sparse_dot(w, idx, val);
    let xn2 = norm2(val);
    (wnorm2 - 2.0 * y as f64 * wx + xn2).max(0.0)
}

/// Metric dot `Σ a_i b_i s_i` — the diagonal-metric inner product
/// `⟨a, b⟩_S` with per-axis weights `s` (the ellipsoid variant passes
/// `s_i = 1/σ_i²`). Chunked with the **same** lane structure as [`dot`]:
/// with `s ≡ 1.0` every lane product `(a·b)·1.0` is exact, so the
/// result is bit-identical to [`dot`] at every length — which is what
/// lets the isotropic ellipsoid reproduce `BallState` exactly.
#[inline]
pub fn dot_scaled(a: &[f32], b: &[f32], s: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), s.len());
    let mut lanes = [0.0f64; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    let mut cs = s.chunks_exact(LANES);
    for ((xa, xb), xs) in ca.by_ref().zip(cb.by_ref()).zip(cs.by_ref()) {
        for l in 0..LANES {
            lanes[l] += xa[l] as f64 * xb[l] as f64 * xs[l];
        }
    }
    let mut acc = reduce8(&lanes);
    for ((x, y), z) in ca.remainder().iter().zip(cb.remainder()).zip(cs.remainder()) {
        acc += *x as f64 * *y as f64 * *z;
    }
    acc
}

/// Metric squared norm `Σ a_i² s_i`.
#[inline]
pub fn norm2_scaled(a: &[f32], s: &[f64]) -> f64 {
    dot_scaled(a, a, s)
}

/// Sparse metric dot `Σ w[idx_k] · val_k · s[idx_k]` — O(nnz).
#[inline]
pub fn sparse_dot_scaled(w: &[f32], s: &[f64], idx: &[u32], val: &[f32]) -> f64 {
    assert_eq!(idx.len(), val.len());
    let mut acc = 0.0f64;
    for k in 0..idx.len() {
        let i = idx[k] as usize;
        acc += w[i] as f64 * val[k] as f64 * s[i];
    }
    acc
}

/// Sparse metric squared norm `Σ val_k² · s[idx_k]` — O(nnz).
#[inline]
pub fn sparse_norm2_scaled(s: &[f64], idx: &[u32], val: &[f32]) -> f64 {
    assert_eq!(idx.len(), val.len());
    let mut acc = 0.0f64;
    for k in 0..idx.len() {
        acc += val[k] as f64 * val[k] as f64 * s[idx[k] as usize];
    }
    acc
}

/// Dense matvec `out[i] = <m[i], v>` for a row-major `(rows, cols)` matrix
/// stored contiguously. Used by the pure-Rust fallback of the predict
/// path and by tests that cross-check the PJRT executables.
pub fn matvec(m: &[f32], rows: usize, cols: usize, v: &[f32], out: &mut [f32]) {
    assert_eq!(m.len(), rows * cols);
    assert_eq!(v.len(), cols);
    assert_eq!(out.len(), rows);
    for r in 0..rows {
        out[r] = dot(&m[r * cols..(r + 1) * cols], v) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn sqdist_matches_naive() {
        let w = [1.0f32, -2.0, 0.5];
        let x = [0.5f32, 1.0, -1.0];
        for y in [-1.0f32, 1.0] {
            let naive: f64 = w
                .iter()
                .zip(x.iter())
                .map(|(&wi, &xi)| (wi as f64 - y as f64 * xi as f64).powi(2))
                .sum();
            assert!((sqdist_scaled(&w, &x, y) - naive).abs() < 1e-12);
        }
    }

    #[test]
    fn blend_into_convex() {
        let mut w = vec![1.0f32, 1.0];
        blend_into(&mut w, &[3.0, 5.0], 1.0, 0.5);
        assert_eq!(w, vec![2.0, 3.0]);
        // beta = 0 is a no-op
        let mut w2 = vec![0.25f32, -0.75];
        blend_into(&mut w2, &[9.0, 9.0], -1.0, 0.0);
        assert_eq!(w2, vec![0.25, -0.75]);
        // beta = 1 lands exactly on y x
        let mut w3 = vec![0.0f32, 0.0];
        blend_into(&mut w3, &[2.0, 4.0], -1.0, 1.0);
        assert_eq!(w3, vec![-2.0, -4.0]);
    }

    #[test]
    fn axpy_scale() {
        let mut a = vec![1.0f32, 2.0];
        axpy(&mut a, 2.0, &[3.0, 4.0]);
        assert_eq!(a, vec![7.0, 10.0]);
        scale(&mut a, 0.5);
        assert_eq!(a, vec![3.5, 5.0]);
    }

    #[test]
    fn matvec_small() {
        let m = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let mut out = [0.0f32; 2];
        matvec(&m, 2, 3, &[1.0, 0.0, -1.0], &mut out);
        assert_eq!(out, [-2.0, -2.0]);
    }

    #[test]
    fn sparse_kernels_match_dense() {
        let w = [1.0f32, -2.0, 0.5, 0.0, 3.0];
        let idx = [0u32, 2, 4];
        let val = [2.0f32, -1.0, 0.5];
        let dense = [2.0f32, 0.0, -1.0, 0.0, 0.5];
        assert_eq!(sparse_dot(&w, &idx, &val), dot(&w, &dense));
        for y in [-1.0f32, 1.0] {
            let got = sparse_sqdist_scaled(&w, norm2(&w), &idx, &val, y);
            let want = sqdist_scaled(&w, &dense, y);
            assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        }
        let mut a = w;
        let mut b = w;
        sparse_axpy(&mut a, 2.0, &idx, &val);
        axpy(&mut b, 2.0, &dense);
        assert_eq!(a, b);
        // empty sparse vector is a no-op / zero
        assert_eq!(sparse_dot(&w, &[], &[]), 0.0);
        sparse_axpy(&mut a, 5.0, &[], &[]);
        assert_eq!(a, b);
    }

    #[test]
    fn sparse_sparse_dot_matches_dense() {
        let a_idx = [0u32, 2, 5, 9];
        let a_val = [1.0f32, -2.0, 0.5, 3.0];
        let b_idx = [2u32, 3, 5, 8];
        let b_val = [4.0f32, 1.0, -1.0, 2.0];
        // overlap at 2 and 5: -2*4 + 0.5*-1 = -8.5
        assert_eq!(sparse_sparse_dot(&a_idx, &a_val, &b_idx, &b_val), -8.5);
        // symmetric
        assert_eq!(sparse_sparse_dot(&b_idx, &b_val, &a_idx, &a_val), -8.5);
        // disjoint and empty
        assert_eq!(sparse_sparse_dot(&[0, 1], &[1.0, 1.0], &[2, 3], &[1.0, 1.0]), 0.0);
        assert_eq!(sparse_sparse_dot(&[], &[], &b_idx, &b_val), 0.0);
    }

    #[test]
    fn sparse_sqdist_clamps_cancellation() {
        // w == y x exactly: the expansion can go tiny-negative in float;
        // the clamp keeps it at 0.
        let w = [3.0f32, 0.0, 4.0];
        let got = sparse_sqdist_scaled(&w, norm2(&w), &[0, 2], &[3.0, 4.0], 1.0);
        assert_eq!(got, 0.0);
    }

    #[test]
    fn scaled_kernels_match_unscaled_at_unit_metric() {
        let w = [1.0f32, -2.0, 0.5, 0.0, 3.0];
        let x = [2.0f32, 0.0, -1.0, 0.0, 0.5];
        let ones = [1.0f64; 5];
        // multiplying by exactly 1.0 is exact: bit-identical to dot/norm2
        assert_eq!(dot_scaled(&w, &x, &ones), dot(&w, &x));
        assert_eq!(norm2_scaled(&w, &ones), norm2(&w));
        let idx = [0u32, 2, 4];
        let val = [2.0f32, -1.0, 0.5];
        assert_eq!(sparse_dot_scaled(&w, &ones, &idx, &val), sparse_dot(&w, &idx, &val));
        assert_eq!(sparse_norm2_scaled(&ones, &idx, &val), norm2(&val));
    }

    #[test]
    fn scaled_kernels_apply_the_metric() {
        let w = [1.0f32, 2.0, 3.0];
        let s = [0.5f64, 2.0, 1.0];
        // Σ w_i² s_i = 0.5 + 8 + 9
        assert!((norm2_scaled(&w, &s) - 17.5).abs() < 1e-12);
        // sparse agrees with dense on the same logical vector
        let idx = [1u32, 2];
        let val = [4.0f32, -1.0];
        let dense = [0.0f32, 4.0, -1.0];
        assert_eq!(sparse_dot_scaled(&w, &s, &idx, &val), dot_scaled(&w, &dense, &s));
        assert_eq!(sparse_norm2_scaled(&s, &idx, &val), norm2_scaled(&dense, &s));
        // empty sparse vector is zero
        assert_eq!(sparse_dot_scaled(&w, &s, &[], &[]), 0.0);
        assert_eq!(sparse_norm2_scaled(&s, &[], &[]), 0.0);
    }

    /// Deterministic pseudo-random f32s in [-1, 1).
    fn vecs(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::rng::Pcg32::seeded(seed);
        (0..n).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect()
    }

    #[test]
    fn lane_chunked_reductions_match_naive_at_every_boundary() {
        // every remainder shape around the 8-lane boundary, plus large
        for n in (0..=20usize).chain([63, 64, 65, 1000]) {
            let a = vecs(n, 11 + n as u64);
            let b = vecs(n, 97 + n as u64);
            let naive_dot: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let tol = 1e-9 * naive_dot.abs().max(1.0);
            assert!((dot(&a, &b) - naive_dot).abs() <= tol, "dot n={n}");
            // n < 8: all lanes zero → bit-identical to the sequential loop
            if n < 8 {
                assert_eq!(dot(&a, &b).to_bits(), naive_dot.to_bits(), "n={n}");
            }
            let naive_n2: f64 = a.iter().map(|&x| x as f64 * x as f64).sum();
            assert!((norm2(&a) - naive_n2).abs() <= 1e-9 * naive_n2.max(1.0), "norm2 n={n}");
            for y in [-1.0f32, 1.0] {
                let naive_d2: f64 = a
                    .iter()
                    .zip(&b)
                    .map(|(&w, &x)| (w as f64 - y as f64 * x as f64).powi(2))
                    .sum();
                let got = sqdist_scaled(&a, &b, y);
                assert!((got - naive_d2).abs() <= 1e-9 * naive_d2.max(1.0), "sqdist n={n}");
            }
            // reductions are deterministic: same input, same bits
            assert_eq!(dot(&a, &b).to_bits(), dot(&a, &b).to_bits());
        }
    }

    #[test]
    fn unit_metric_is_bit_identical_at_every_length() {
        // the isotropic-ellipsoid ≡ BallState anchor must hold on both
        // sides of the lane boundary, not just at small dims
        for n in (0..=20usize).chain([64, 1000]) {
            let a = vecs(n, 3 + n as u64);
            let b = vecs(n, 5 + n as u64);
            let ones = vec![1.0f64; n];
            assert_eq!(dot_scaled(&a, &b, &ones).to_bits(), dot(&a, &b).to_bits(), "n={n}");
            assert_eq!(norm2_scaled(&a, &ones).to_bits(), norm2(&a).to_bits(), "n={n}");
        }
    }

    #[test]
    fn lane_chunked_elementwise_match_naive_exactly() {
        // no accumulator → chunking must not change a single bit
        for n in (0..=20usize).chain([64, 1000]) {
            let base = vecs(n, 23 + n as u64);
            let x = vecs(n, 41 + n as u64);
            for (s, y, beta) in [(0.5f32, 1.0f32, 0.25f32), (-2.0, -1.0, 0.75)] {
                let mut got = base.clone();
                let mut want = base.clone();
                axpy(&mut got, s, &x);
                for i in 0..n {
                    want[i] += s * x[i];
                }
                assert_eq!(got, want, "axpy n={n}");

                let mut got = base.clone();
                let mut want = base.clone();
                blend_into(&mut got, &x, y, beta);
                let (omb, by) = (1.0 - beta, beta * y);
                for i in 0..n {
                    want[i] = omb * want[i] + by * x[i];
                }
                assert_eq!(got, want, "blend n={n}");

                let mut got = base.clone();
                let mut want = base.clone();
                scale(&mut got, s);
                for v in want.iter_mut() {
                    *v *= s;
                }
                assert_eq!(got, want, "scale n={n}");
            }
        }
    }

    #[test]
    fn f64_accumulation_beats_f32() {
        // A catastrophic-cancellation-ish case: large equal components.
        let n = 4096;
        let a = vec![1000.0f32; n];
        let b = vec![1e-3f32; n];
        let got = dot(&a, &b);
        assert!((got - n as f64).abs() < 1e-6 * n as f64);
    }
}
