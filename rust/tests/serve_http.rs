//! Integration: the network serving subsystem end-to-end over real TCP.
//!
//! Covers the PR's acceptance criteria: train a model, start the server
//! in-process, hit it with concurrent `/train` and `/predict` traffic
//! from multiple client threads, and assert that (a) no request ever
//! observes a torn model — scores are always finite and stamped with a
//! published snapshot version, (b) shed requests get an explicit reject
//! rather than a hang, and (c) the loadgen harness writes a
//! `BENCH_serve.json` with non-zero QPS and p50/p90/p99 — all with zero
//! external dependencies.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use streamsvm::data::{Example, Features};
use streamsvm::prop::gen;
use streamsvm::rng::Pcg32;
use streamsvm::server::json::Json;
use streamsvm::server::{serve, LoadClient, LoadgenConfig, ServerConfig};
use streamsvm::sketch::codec::MebSketch;
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

const DIM: usize = 6;

fn toy(n: usize, seed: u64) -> Vec<Example> {
    let mut rng = Pcg32::seeded(seed);
    let (xs, ys) = gen::labeled_points(&mut rng, n, DIM, 1.0, 1.0);
    xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect()
}

fn trained_model() -> StreamSvm {
    StreamSvm::fit(toy(300, 1).iter(), DIM, &TrainOptions::default())
}

#[test]
fn concurrent_train_and_predict_with_hot_swap_and_loadgen() {
    let dir = std::env::temp_dir().join(format!("ssvm_serve_http_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let live_path = dir.join("live.meb");

    let cfg = ServerConfig {
        threads: 8,
        conn_queue: 32,
        train_queue: 4096,
        republish_every: 8,
        snapshot: Some(live_path.clone()),
        read_timeout: Duration::from_secs(2),
        tag: "itest".into(),
        ..Default::default()
    };
    let handle = serve(trained_model(), cfg).unwrap();
    let addr = handle.addr();

    // ---- concurrent traffic: 4 predict threads + 2 train threads
    let max_version = Arc::new(AtomicU64::new(0));
    let accepted_trains = Arc::new(AtomicU64::new(0));
    let predictors: Vec<_> = (0..4)
        .map(|k| {
            let examples = toy(60, 100 + k);
            let maxv = max_version.clone();
            std::thread::spawn(move || {
                let mut client = LoadClient::connect(addr, Duration::from_secs(2)).unwrap();
                let mut last_version = 0u64;
                for e in &examples {
                    let o = client.predict_features(&e.x).unwrap();
                    // every reply is a 2xx from a published snapshot with
                    // a finite score — a torn model would break this
                    assert_eq!(o.status, 200);
                    let score = o.score.expect("predict reply carries a score");
                    assert!(score.is_finite(), "non-finite score {score}");
                    let v = o.version.expect("predict reply carries a version");
                    assert!(v >= 1);
                    assert!(v >= last_version, "version went backwards: {v} < {last_version}");
                    last_version = v;
                }
                maxv.fetch_max(last_version, Ordering::Relaxed);
            })
        })
        .collect();
    let trainers: Vec<_> = (0..2)
        .map(|k| {
            let examples = toy(120, 200 + k);
            let accepted = accepted_trains.clone();
            std::thread::spawn(move || {
                let mut client = LoadClient::connect(addr, Duration::from_secs(2)).unwrap();
                for e in &examples {
                    let o = client.train_features(&e.x, e.y).unwrap();
                    // either explicitly accepted or explicitly shed
                    assert!(
                        o.status == 202 || o.status == 429,
                        "unexpected train status {}",
                        o.status
                    );
                    if o.status == 202 {
                        accepted.fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
        })
        .collect();
    for t in predictors.into_iter().chain(trainers) {
        t.join().unwrap();
    }

    // hot swap happened: the trainer republished while predicts flew
    let accepted = accepted_trains.load(Ordering::Relaxed);
    assert!(accepted > 0, "no train request was accepted");
    assert!(max_version.load(Ordering::Relaxed) >= 1, "no published snapshot observed");

    // ---- stats endpoint reflects the traffic
    let mut client = LoadClient::connect(addr, Duration::from_secs(2)).unwrap();
    let stats = client.stats().unwrap();
    let ep = stats.get("endpoints").unwrap();
    let predict_ok = ep.get("predict").unwrap().get("ok").unwrap().as_f64().unwrap();
    assert!(predict_ok >= 240.0, "predict ok = {predict_ok}");
    for q in ["p50_us", "p90_us", "p99_us"] {
        assert!(
            ep.get("predict").unwrap().get(q).unwrap().as_f64().is_some(),
            "missing {q}"
        );
    }

    // ---- live snapshot: /snapshot bytes decode, and the republished
    // .meb file on disk decodes too
    let bytes = client.snapshot().unwrap();
    let sk = MebSketch::decode(&bytes).unwrap();
    assert_eq!(sk.dim, DIM);
    assert_eq!(sk.tag, "itest");
    let disk = MebSketch::read_from(&live_path).unwrap();
    assert_eq!(disk.dim, DIM);
    drop(client);

    // ---- loadgen writes BENCH_serve.json with non-zero qps + quantiles
    let bench_path = dir.join("BENCH_serve.json");
    let lg = LoadgenConfig {
        addr: addr.to_string(),
        threads: 4,
        requests: 400,
        qps: 2000.0,
        train_share: 0.25,
        read_timeout: Duration::from_secs(2),
        seed: 7,
    };
    let report = streamsvm::server::run_loadgen(&lg, &toy(100, 9)).unwrap();
    assert_eq!(report.sent, 400);
    assert!(report.ok > 0, "loadgen got no 2xx: {}", report.summary());
    assert_eq!(report.errors, 0, "loadgen errors: {}", report.summary());
    report.write_json(&bench_path).unwrap();
    let bench = Json::parse(&std::fs::read_to_string(&bench_path).unwrap()).unwrap();
    assert!(bench.get("qps_achieved").unwrap().as_f64().unwrap() > 0.0);
    let lat = bench.get("latency_us").unwrap();
    for q in ["p50", "p90", "p99"] {
        let v = lat.get(q).unwrap().as_f64().unwrap();
        assert!(v > 0.0, "latency quantile {q} = {v}");
    }

    // ---- graceful shutdown absorbs every accepted /train example
    let report = handle.shutdown().unwrap();
    assert!(report.trained >= accepted, "trained {} < accepted {accepted}", report.trained);
    assert!(report.version > 1, "hot swap never republished");
    assert!(report.model.examples_seen() >= 300 + accepted as usize);
    assert!(report.requests_ok > 0);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_stream_feeds_the_trainer_while_serving() {
    use streamsvm::eval::accuracy;

    let dir = std::env::temp_dir().join(format!("ssvm_train_stream_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let stream_path = dir.join("stream.libsvm");
    let live_path = dir.join("live.meb");

    // One coherent pool: toy(n, seed) draws a fresh class-mean direction
    // per seed, so initial training, the stream file, wire traffic and
    // the accuracy eval must all slice the same draw.
    let pool = toy(1000, 1);
    let initial = StreamSvm::fit(pool[..300].iter(), DIM, &TrainOptions::default());

    // Write a LIBSVM file the background trainer will consume: 240 good
    // rows (1-based indices, zeros dropped) plus one poisoned row the
    // tolerant reader must skip without truncating the stream.
    let rows: Vec<Example> = pool[300..540].to_vec();
    {
        use std::io::Write as _;
        let mut f = std::io::BufWriter::new(std::fs::File::create(&stream_path).unwrap());
        for (i, e) in rows.iter().enumerate() {
            if i == 100 {
                writeln!(f, "+1 1:nan").unwrap();
            }
            write!(f, "{}", if e.y > 0.0 { "+1" } else { "-1" }).unwrap();
            for (j, v) in e.x.iter_nonzero() {
                write!(f, " {}:{}", j + 1, v).unwrap();
            }
            writeln!(f).unwrap();
        }
    }

    let cfg = ServerConfig {
        threads: 4,
        conn_queue: 16,
        train_queue: 1024,
        republish_every: 16,
        snapshot: Some(live_path.clone()),
        read_timeout: Duration::from_secs(2),
        tag: "stream".into(),
        train_stream: Some(stream_path.clone()),
        ..Default::default()
    };
    let handle = serve(initial, cfg).unwrap();
    let addr = handle.addr();

    // Concurrent wire traffic while the file stream drains: /train posts
    // interleave with stream rows, /predict stays healthy throughout.
    let wire_accepted = Arc::new(AtomicU64::new(0));
    let trainer_thread = {
        let examples: Vec<Example> = pool[540..620].to_vec();
        let accepted = wire_accepted.clone();
        std::thread::spawn(move || {
            let mut client = LoadClient::connect(addr, Duration::from_secs(2)).unwrap();
            for e in &examples {
                let o = client.train_features(&e.x, e.y).unwrap();
                assert!(o.status == 202 || o.status == 429, "train status {}", o.status);
                if o.status == 202 {
                    accepted.fetch_add(1, Ordering::Relaxed);
                }
            }
        })
    };
    let predictor = {
        let examples: Vec<Example> = pool[620..680].to_vec();
        std::thread::spawn(move || {
            let mut client = LoadClient::connect(addr, Duration::from_secs(2)).unwrap();
            for e in &examples {
                let o = client.predict_features(&e.x).unwrap();
                assert_eq!(o.status, 200);
                assert!(o.score.unwrap().is_finite());
            }
        })
    };
    trainer_thread.join().unwrap();
    predictor.join().unwrap();

    // /stats reports live stream progress; poll until the file is done
    // (the trainer consumes it at full speed — this is generous).
    let mut client = LoadClient::connect(addr, Duration::from_secs(2)).unwrap();
    let mut done = false;
    let mut last = None;
    for _ in 0..500 {
        let stats = client.stats().unwrap();
        let stream = stats.get("stream").expect("stats carries a stream object").clone();
        if stream.get("done").and_then(|v| v.as_bool()) == Some(true) {
            done = true;
            last = Some(stream);
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(done, "train stream never finished");
    let stream = last.unwrap();
    assert_eq!(
        stream.get("rows").and_then(|v| v.as_f64()),
        Some(240.0),
        "all good rows absorbed"
    );
    assert_eq!(
        stream.get("skipped").and_then(|v| v.as_f64()),
        Some(1.0),
        "the poisoned row was skipped, not fatal"
    );
    drop(client);

    // Shutdown: the report carries the stream accounting, the final
    // model has absorbed initial + stream + accepted wire examples, and
    // its dimension/accuracy are intact.
    let accepted = wire_accepted.load(Ordering::Relaxed);
    let report = handle.shutdown().unwrap();
    assert_eq!(report.stream_rows, 240);
    assert!(report.stream_done);
    assert!(report.trained >= accepted, "trained {} < accepted {accepted}", report.trained);
    assert_eq!(report.model.dim(), DIM);
    assert!(
        report.model.examples_seen() >= 300 + 240 + accepted as usize,
        "examples_seen {} < initial+stream+wire",
        report.model.examples_seen()
    );
    assert!(report.version > 1, "stream training never republished");
    let acc = accuracy(&report.model, &pool[800..]);
    assert!(acc > 0.8, "final model degraded: acc {acc:.3}");

    // the snapshot on disk reflects the fully-streamed model
    let disk = MebSketch::read_from(&live_path).unwrap();
    assert_eq!(disk.dim, DIM);
    assert!(disk.seen >= 300 + 240);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn overload_gets_explicit_reject_never_a_hang() {
    // One handler, rendezvous connection queue: while the handler owns a
    // connection, any further connection must be shed with an explicit
    // 429 — within the read timeout, i.e. never a hang.
    let cfg = ServerConfig {
        threads: 1,
        conn_queue: 0,
        train_queue: 4,
        // generous idle cutoff so a slow CI box can't time out the held
        // connection mid-test (drop(held) unblocks the handler instantly)
        read_timeout: Duration::from_secs(10),
        tag: "shed".into(),
        ..Default::default()
    };
    let handle = serve(trained_model(), cfg).unwrap();
    let addr = handle.addr();
    let x = vec![0.5f32; DIM];

    // Occupy the single handler with a keep-alive connection. With a
    // rendezvous queue the very first connection races handler-thread
    // startup (it sheds until the handler blocks in recv), so retry
    // until one connection gets a 200 — from then on the handler owns it.
    let mut held = None;
    for _ in 0..100 {
        let mut c = LoadClient::connect(addr, Duration::from_secs(2)).unwrap();
        match c.predict(&x) {
            Ok(o) if o.status == 200 => {
                held = Some(c);
                break;
            }
            _ => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    let mut held = held.expect("could not occupy the handler");

    // subsequent connections are shed explicitly
    let mut sheds = 0;
    for _ in 0..3 {
        let mut extra = LoadClient::connect(addr, Duration::from_secs(2)).unwrap();
        match extra.predict(&x) {
            Ok(o) => {
                assert_eq!(o.status, 429, "expected shed, got {}", o.status);
                assert!(o.closed, "shed responses close the connection");
                sheds += 1;
            }
            // a torn-down connection (reset racing the reply) is still an
            // explicit, immediate reject — the key property is no hang
            Err(_) => sheds += 1,
        }
    }
    assert_eq!(sheds, 3);

    // the held connection still works fine afterwards
    let o = held.predict(&x).unwrap();
    assert_eq!(o.status, 200);
    drop(held);

    let report = handle.shutdown().unwrap();
    assert!(report.conns_shed >= 3, "conns_shed = {}", report.conns_shed);
    assert_eq!(report.trained, 0);
}

#[test]
fn train_queue_full_is_an_explicit_429() {
    // Tiny train queue + slow drain (republish_every=1 makes the trainer
    // do real work): flood /train on one connection until a 429 appears.
    let cfg = ServerConfig {
        threads: 2,
        conn_queue: 8,
        train_queue: 1,
        republish_every: 1,
        read_timeout: Duration::from_secs(2),
        tag: "full".into(),
        ..Default::default()
    };
    let handle = serve(trained_model(), cfg).unwrap();
    let addr = handle.addr();
    let mut client = LoadClient::connect(addr, Duration::from_secs(2)).unwrap();
    let exs = toy(400, 3);
    let (mut accepted, mut shed) = (0u32, 0u32);
    for e in &exs {
        let o = client.train_features(&e.x, e.y).unwrap();
        match o.status {
            202 => accepted += 1,
            429 => shed += 1,
            other => panic!("unexpected status {other}"),
        }
    }
    assert!(accepted > 0, "nothing accepted");
    // every outcome was explicit: accepted or shed, nothing hung or lost
    assert_eq!(accepted + shed, 400);
    drop(client);
    let report = handle.shutdown().unwrap();
    assert_eq!(report.trained, accepted as u64, "every accepted example absorbed");
}

#[test]
fn predict_batch_mixes_dense_and_sparse_rows_over_the_wire() {
    let cfg = ServerConfig {
        threads: 2,
        conn_queue: 8,
        train_queue: 16,
        read_timeout: Duration::from_secs(2),
        tag: "batch".into(),
        ..Default::default()
    };
    let handle = serve(trained_model(), cfg).unwrap();
    let mut client = LoadClient::connect(handle.addr(), Duration::from_secs(2)).unwrap();

    // one batch, mixed representations: a dense row and its sparse twin
    // must score identically, against one snapshot version
    let dense = Features::Dense(vec![0.0, 1.5, 0.0, 0.0, -2.0, 0.0]);
    let sparse = dense.to_sparse();
    assert!(matches!(&sparse, Features::Sparse { .. }));
    let (status, body) = client
        .predict_batch_features(&[dense.clone(), sparse.clone(), Features::Dense(vec![0.0; DIM])])
        .unwrap();
    assert_eq!(status, 200);
    let scores = body.get("scores").unwrap().as_array().unwrap();
    assert_eq!(scores.len(), 3);
    assert_eq!(scores[0].as_f64(), scores[1].as_f64(), "sparse row must score like dense");
    assert_eq!(scores[2].as_f64(), Some(0.0));
    assert!(body.get("version").unwrap().as_f64().unwrap() >= 1.0);

    // same idx/val validation as /predict, surfaced with the row index:
    // single /predict accepts the same sparse shape in this process
    let op = client.predict_features(&sparse).unwrap();
    assert_eq!(op.status, 200);
    assert_eq!(op.score, scores[0].as_f64());

    drop(client);
    handle.shutdown().unwrap();
}

#[test]
fn metrics_and_trace_are_served_over_tcp() {
    let cfg = ServerConfig {
        threads: 2,
        conn_queue: 8,
        train_queue: 64,
        republish_every: 4,
        read_timeout: Duration::from_secs(2),
        tag: "obs".into(),
        ..Default::default()
    };
    let handle = serve(trained_model(), cfg).unwrap();
    let mut client = LoadClient::connect(handle.addr(), Duration::from_secs(2)).unwrap();

    // traffic burst: predicts + a few absorbed trains
    let exs = toy(50, 5);
    for e in &exs {
        assert_eq!(client.predict_features(&e.x).unwrap().status, 200);
    }
    for e in &exs[..10] {
        let o = client.train_features(&e.x, e.y).unwrap();
        assert!(o.status == 202 || o.status == 429, "train status {}", o.status);
    }

    // scrape /metrics: strict grammar + request counters reflect traffic
    let before = client.get_text("/metrics").unwrap();
    let fams = streamsvm::obs::prom::check_exposition(&before)
        .unwrap_or_else(|e| panic!("invalid exposition: {e}\n{before}"));
    assert!(fams >= 20, "only {fams} metric families");
    let req_before =
        streamsvm::obs::prom::sum_metric(&before, "pallas_requests_total").unwrap();
    assert!(req_before >= 50.0, "requests_total = {req_before}");

    // a second burst strictly increases the counter
    for e in &exs {
        assert_eq!(client.predict_features(&e.x).unwrap().status, 200);
    }
    let after = client.get_text("/metrics").unwrap();
    streamsvm::obs::prom::check_exposition(&after)
        .unwrap_or_else(|e| panic!("invalid exposition after burst: {e}"));
    let req_after = streamsvm::obs::prom::sum_metric(&after, "pallas_requests_total").unwrap();
    assert!(
        req_after >= req_before + 50.0,
        "requests_total {req_before} -> {req_after}"
    );

    // live training gauges and latency buckets are exposed
    assert!(after.contains("pallas_train_radius"), "missing training gauge");
    assert!(
        after.contains("pallas_request_latency_seconds_bucket"),
        "missing latency histogram"
    );
    assert!(after.contains("pallas_model_generation"), "missing generation gauge");

    // /trace serves the ring buffer as parseable JSON
    let trace = client.get_text("/trace").unwrap();
    let j = Json::parse(&trace).unwrap_or_else(|e| panic!("unparseable /trace: {e}"));
    assert!(j.get("events").and_then(|v| v.as_array()).is_some(), "no events array");

    drop(client);
    handle.shutdown().unwrap();
}

#[test]
fn traceparent_propagates_and_debug_trace_serves_span_trees() {
    let cfg = ServerConfig {
        threads: 4,
        conn_queue: 16,
        train_queue: 64,
        republish_every: 8,
        read_timeout: Duration::from_secs(2),
        tag: "traced".into(),
        ..Default::default()
    };
    let handle = serve(trained_model(), cfg).unwrap();
    let addr = handle.addr();
    let mut client = LoadClient::connect(addr, Duration::from_secs(2)).unwrap();
    let body: &[u8] = br#"{"x": [0.5, 0.0, 0.0, 0.0, 0.0, 0.0]}"#;

    // Every response reports its server-side duration, traced or not —
    // loadgen cross-checks wire latency against this header.
    let plain = client.request("POST", "/predict", body, &[]).unwrap();
    assert_eq!(plain.status, 200);
    let _dur: u64 = plain
        .header("x-pallas-dur-us")
        .expect("x-pallas-dur-us on every response")
        .trim()
        .parse()
        .expect("x-pallas-dur-us is numeric");

    // A request carrying a W3C traceparent echoes the same trace id and
    // is always retained for /debug/trace, regardless of latency.
    let hex = "4bf92f3577b34da6a3ce929d0e0e4736";
    let tp = format!("00-{hex}-00f067aa0ba902b7-01");
    let resp = client.request("POST", "/predict", body, &[("traceparent", tp)]).unwrap();
    assert_eq!(resp.status, 200);
    let echoed = resp.header("traceparent").expect("traced reply echoes traceparent");
    assert!(echoed.contains(hex), "echoed `{echoed}` lost the trace id");

    // ... and the whole span tree round-trips through /debug/trace/<id>.
    let fetched = client.get_text(&format!("/debug/trace/{hex}")).unwrap();
    let j = Json::parse(&fetched).unwrap_or_else(|e| panic!("unparseable trace: {e}"));
    assert_eq!(j.get("trace_id").and_then(|v| v.as_str()), Some(hex));
    assert!(j.get("root_dur_us").and_then(|v| v.as_f64()).is_some(), "root never finished");
    let spans = j.get("spans").and_then(|v| v.as_array()).expect("spans array");
    assert!(!spans.is_empty(), "trace has no spans");
    let root_id = j.get("root").and_then(|v| v.as_f64()).expect("root id");
    let root = spans
        .iter()
        .find(|s| s.get("id").and_then(|v| v.as_f64()) == Some(root_id))
        .expect("root span present in the tree");
    let fields = root.get("fields").expect("root span carries request fields");
    assert_eq!(fields.get("path").and_then(|v| v.as_str()), Some("/predict"));
    assert_eq!(fields.get("status").and_then(|v| v.as_f64()), Some(200.0));

    // An unknown-but-valid id is an explicit 404; garbage is a 400.
    let miss = client
        .request("GET", &format!("/debug/trace/{}", "f".repeat(32)), b"", &[])
        .unwrap();
    assert_eq!(miss.status, 404);
    let bad = client.request("GET", "/debug/trace/not-hex", b"", &[]).unwrap();
    assert_eq!(bad.status, 400);
    drop(client);

    // Concurrent traced load: distinct trace ids never cross-talk, and
    // each one is retrievable while the others are still in flight.
    let workers: Vec<_> = (0..4u64)
        .map(|k| {
            std::thread::spawn(move || {
                let mut c = LoadClient::connect(addr, Duration::from_secs(2)).unwrap();
                for i in 0..8u64 {
                    let id = format!("{:032x}", 0xabc0_0000u128 + ((k << 8) | i) as u128 + 1);
                    let tp = format!("00-{id}-00f067aa0ba902b7-01");
                    let body = br#"{"x": [0.5, 0.0, 0.0, 0.0, 0.0, 0.0]}"#;
                    let r = c.request("POST", "/predict", body, &[("traceparent", tp)]).unwrap();
                    assert_eq!(r.status, 200);
                    assert!(r.header("traceparent").unwrap().contains(&id));
                    let t = c.get_text(&format!("/debug/trace/{id}")).unwrap();
                    let j = Json::parse(&t).unwrap();
                    assert_eq!(j.get("trace_id").and_then(|v| v.as_str()), Some(id.as_str()));
                }
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }

    // the retained-trace listing at the bare path parses and is bounded
    let mut client = LoadClient::connect(addr, Duration::from_secs(2)).unwrap();
    let listing = client.get_text("/debug/trace").unwrap();
    let j = Json::parse(&listing).unwrap();
    let traces = j.get("traces").and_then(|v| v.as_array()).expect("traces array");
    assert!(!traces.is_empty() && traces.len() <= 128, "listing size {}", traces.len());
    drop(client);
    handle.shutdown().unwrap();
}

/// The PR's variant-generic serving criterion: every learner variant
/// runs the full train + predict + snapshot flow, `/stats` names the
/// variant, the `.meb` snapshot carries the v4 variant tag, and a
/// learner restored from those bytes scores *bit-identically* to the
/// model the server was started with.
#[test]
fn every_variant_serves_trains_and_snapshots_bit_identically() {
    use streamsvm::svm::learner::{AnyLearner, Variant};

    for variant in Variant::ALL {
        let tag = format!("v-{}", variant.name());
        let cfg = ServerConfig {
            threads: 2,
            conn_queue: 8,
            train_queue: 64,
            republish_every: 4,
            read_timeout: Duration::from_secs(2),
            tag: tag.clone(),
            ..Default::default()
        };
        // the fit is deterministic, so this local twin is the exact
        // model the server starts from
        let opts = TrainOptions::default();
        let reference = AnyLearner::fit(toy(300, 1).iter(), variant, DIM, opts);
        let handle = serve(AnyLearner::fit(toy(300, 1).iter(), variant, DIM, opts), cfg).unwrap();
        let mut client = LoadClient::connect(handle.addr(), Duration::from_secs(2)).unwrap();

        // /stats names the serving variant
        let stats = client.stats().unwrap();
        assert_eq!(
            stats.get("variant").and_then(|v| v.as_str()),
            Some(variant.name()),
            "{variant}: /stats variant field"
        );

        // predict is healthy and matches the local twin
        let probes = toy(40, 7);
        for e in &probes {
            let o = client.predict_features(&e.x).unwrap();
            assert_eq!(o.status, 200, "{variant}");
            let got = o.score.expect("score");
            let want = reference.score(&e.x.dense());
            assert!(
                (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                "{variant}: wire score {got} vs local {want}"
            );
        }

        // /snapshot: v4 bytes carry the variant tag and restore a
        // learner with bit-identical scores (taken before any /train
        // traffic, so the served model is still the reference fit)
        let bytes = client.snapshot().unwrap();
        let sk = MebSketch::decode(&bytes).unwrap();
        assert_eq!(sk.variant, variant, "snapshot variant tag");
        assert_eq!(sk.tag, tag);
        assert_eq!(sk.dim, DIM);
        let restored = sk.to_learner().unwrap();
        assert_eq!(restored.variant(), variant);
        assert_eq!(restored.examples_seen(), reference.examples_seen(), "{variant}");
        assert_eq!(
            restored.radius().to_bits(),
            reference.radius().to_bits(),
            "{variant}: restored radius not bit-identical"
        );
        for e in &probes {
            let x = e.x.dense();
            assert_eq!(
                restored.score(&x).to_bits(),
                reference.score(&x).to_bits(),
                "{variant}: restored score not bit-identical"
            );
        }

        // /train is absorbed by the same-variant background trainer
        let mut accepted = 0u64;
        for e in &toy(30, 8) {
            let o = client.train_features(&e.x, e.y).unwrap();
            assert!(o.status == 202 || o.status == 429, "{variant}: train status {}", o.status);
            if o.status == 202 {
                accepted += 1;
            }
        }
        drop(client);

        let report = handle.shutdown().unwrap();
        assert_eq!(report.model.variant(), variant, "trainer switched variants");
        assert!(report.trained >= accepted, "{variant}: trained {} < {accepted}", report.trained);
        assert!(report.model.examples_seen() >= 300 + accepted as usize, "{variant}");
    }
}

#[test]
fn sparse_payloads_round_trip_over_the_wire() {
    let cfg = ServerConfig {
        threads: 2,
        conn_queue: 8,
        train_queue: 64,
        republish_every: 1,
        read_timeout: Duration::from_secs(2),
        tag: "sparse".into(),
        ..Default::default()
    };
    let handle = serve(trained_model(), cfg).unwrap();
    let addr = handle.addr();
    let mut client = LoadClient::connect(addr, Duration::from_secs(2)).unwrap();

    // the same vector, dense and sparse: identical score from the server
    let dense = Features::Dense(vec![0.0, 1.5, 0.0, 0.0, -2.0, 0.0]);
    let sparse = dense.to_sparse();
    assert!(matches!(&sparse, Features::Sparse { .. }));
    let od = client.predict_features(&dense).unwrap();
    let os = client.predict_features(&sparse).unwrap();
    assert_eq!(od.status, 200);
    assert_eq!(os.status, 200);
    assert_eq!(od.score, os.score, "sparse score must match dense score");

    // sparse training is accepted and absorbed
    let o = client.train_features(&sparse, 1.0).unwrap();
    assert_eq!(o.status, 202);
    drop(client);
    let report = handle.shutdown().unwrap();
    assert_eq!(report.trained, 1);
}
