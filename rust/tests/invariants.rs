//! Cross-module invariant suite (property tests over the whole public
//! API) and failure injection.
//!
//! Enclosure is a statement about the *augmented* space: the center
//! carries slack mass on the indices it absorbed, so the line-5 distance
//! formula (which assumes no overlap) OVERESTIMATES the distance of
//! previously-absorbed points. The `SlackTracker` below materializes the
//! center's per-index slack coefficients next to the algorithm under
//! test, giving the exact augmented distance for every stream point.

use streamsvm::data::Example;
use streamsvm::prop::{check, gen, PropConfig};
use streamsvm::rng::Pcg32;
use streamsvm::svm::ball::BallState;
use streamsvm::svm::lookahead::LookaheadSvm;
use streamsvm::svm::meb::solve_meb_points;
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::{SlackMode, TrainOptions};

/// Explicit per-stream-index slack coefficients of the MEB center.
struct SlackTracker {
    /// coeff[i] = center's coordinate on index i's slack axis (already
    /// scaled by √s²).
    coeff: Vec<f64>,
    s2: f64,
}

impl SlackTracker {
    fn new(n: usize, s2: f64) -> Self {
        SlackTracker { coeff: vec![0.0; n], s2 }
    }

    /// Center moved: `c ← (1−β) c + β φ̃(z_i)`.
    fn blend(&mut self, i: usize, beta: f64) {
        for c in self.coeff.iter_mut() {
            *c *= 1.0 - beta;
        }
        self.coeff[i] += beta * self.s2.sqrt();
    }

    /// Lookahead merge: `c ← (1−Σμ) c + Σ μ_k φ̃(z_{b_k})`.
    fn merge(&mut self, buffer: &[usize], mu: &[f64]) {
        let tot: f64 = mu.iter().sum();
        for c in self.coeff.iter_mut() {
            *c *= 1.0 - tot;
        }
        for (k, &i) in buffer.iter().enumerate() {
            self.coeff[i] += mu[k] * self.s2.sqrt();
        }
    }

    /// Exact augmented squared distance of point `i` to the center whose
    /// explicit part is `w`.
    fn sqdist(&self, w: &[f32], x: &[f32], y: f32, i: usize) -> f64 {
        let feat = streamsvm::linalg::sqdist_scaled(w, x, y);
        let slack_mass: f64 = self.coeff.iter().map(|c| c * c).sum();
        feat + slack_mass - 2.0 * self.coeff[i] * self.s2.sqrt() + self.s2
    }
}

/// Run Algorithm 1 while tracking slack explicitly; returns (ball, tracker).
fn run_algo1_tracked(
    xs: &[Vec<f32>],
    ys: &[f32],
    opts: &TrainOptions,
) -> (BallState, SlackTracker) {
    let mut tracker = SlackTracker::new(xs.len(), opts.s2());
    let mut ball = BallState::init(&xs[0], ys[0], opts);
    tracker.blend(0, 1.0);
    for (i, (x, y)) in xs.iter().zip(ys).enumerate().skip(1) {
        let d = ball.distance(x, *y, opts);
        if d >= ball.r {
            // replicate the update to recover beta
            let beta = 0.5 * (1.0 - ball.r / d);
            ball.try_update(x, *y, opts);
            tracker.blend(i, beta);
        }
    }
    (ball, tracker)
}

#[test]
fn algorithm1_final_ball_encloses_entire_stream() {
    // The streaming guarantee: every streamed point lies inside the final
    // ball — in the exact augmented geometry.
    check(
        "algo1-stream-enclosure",
        PropConfig { cases: 48, seed: 0xE1 },
        |rng, _| {
            let d = gen::dim(rng);
            let n = 16 + rng.below(150);
            let (xs, ys) = gen::labeled_points(rng, n, d, 1.5, 0.4);
            // Consistent slack only: in Paper mode with C ≠ 1 the
            // pseudocode's distance (… + 1/C) and its slack-mass update
            // (+β²·1) disagree, so no explicit space reproduces its
            // geometry exactly — the documented DESIGN.md §3 quirk.
            // (Paper ≡ Consistent at C = 1, which the C = 1.0 draw covers.)
            let opts = TrainOptions {
                c: [0.1, 1.0, 10.0][rng.below(3)],
                slack_mode: SlackMode::Consistent,
                ..TrainOptions::default()
            };
            let (ball, tracker) = run_algo1_tracked(&xs, &ys, &opts);
            let bw = ball.weights();
            for (i, (x, y)) in xs.iter().zip(&ys).enumerate() {
                let dist = tracker.sqdist(&bw, x, *y, i).sqrt();
                if dist > ball.r * (1.0 + 2e-3) + 1e-9 {
                    return Err(format!("point {i}: d {dist} > R {}", ball.r));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn algorithm2_final_ball_encloses_entire_stream() {
    use streamsvm::svm::meb::solve_merge;
    check(
        "algo2-stream-enclosure",
        PropConfig { cases: 24, seed: 0xE2 },
        |rng, _| {
            let d = gen::dim(rng);
            let n = 16 + rng.below(120);
            let l = 2 + rng.below(10);
            let (xs, ys) = gen::labeled_points(rng, n, d, 1.5, 0.4);
            let opts = TrainOptions::default().with_lookahead(l);
            // replicate Algorithm 2 with tracked slack
            let mut tracker = SlackTracker::new(n, opts.s2());
            let mut ball = BallState::init(&xs[0], ys[0], &opts);
            tracker.blend(0, 1.0);
            let mut buf: Vec<usize> = Vec::new();
            let mut flush =
                |ball: &mut BallState, tracker: &mut SlackTracker, buf: &mut Vec<usize>| {
                    if buf.is_empty() {
                        return;
                    }
                    let bx: Vec<streamsvm::data::FeaturesView> = buf
                        .iter()
                        .map(|&i| streamsvm::data::FeaturesView::Dense(xs[i].as_slice()))
                        .collect();
                    let by: Vec<f32> = buf.iter().map(|&i| ys[i]).collect();
                    let res = solve_merge(ball, &bx, &by, &opts);
                    tracker.merge(buf, &res.mu);
                    *ball = res.ball;
                    buf.clear();
                };
            for i in 1..n {
                let dist = ball.distance(&xs[i], ys[i], &opts);
                if dist >= ball.r {
                    buf.push(i);
                    if buf.len() >= l {
                        flush(&mut ball, &mut tracker, &mut buf);
                    }
                }
            }
            flush(&mut ball, &mut tracker, &mut buf);
            let bw = ball.weights();
            for (i, (x, y)) in xs.iter().zip(&ys).enumerate() {
                let dist = tracker.sqdist(&bw, x, *y, i).sqrt();
                if dist > ball.r * (1.0 + 2e-3) + 1e-9 {
                    return Err(format!("L={l} point {i}: d {dist} > R {}", ball.r));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn streaming_radius_within_theory_band_of_optimum() {
    // Zarrabi-Zadeh & Chan: the streamed radius is between R* and 1.5 R*.
    // R* is estimated with a long Badoiu-Clarkson run (itself (1+eps)),
    // so the band gets a small tolerance on both sides.
    check(
        "radius-approximation-band",
        PropConfig { cases: 24, seed: 0xE3 },
        |rng, _| {
            let d = gen::dim(rng);
            let n = 24 + rng.below(100);
            let (xs, ys) = gen::labeled_points(rng, n, d, 2.0, 0.3);
            let opts = TrainOptions::default();
            let mut m = StreamSvm::new(d, opts);
            for (x, y) in xs.iter().zip(&ys) {
                m.observe(x, *y);
            }
            let xrefs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let opt = solve_meb_points(&xrefs, &ys, opts.s2(), 3000);
            let ratio = m.radius() / opt.r;
            if !(0.98..=1.55).contains(&ratio) {
                return Err(format!(
                    "ratio {ratio} outside [1, 1.5] band (R={}, R*={})",
                    m.radius(),
                    opt.r
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn stream_order_changes_radius_within_theory_spread() {
    // Different stream orders give different radii (that's the 3/2
    // slack), but the spread stays within the theory band.
    let mut rng = Pcg32::seeded(0xE4);
    let (xs, ys) = gen::labeled_points(&mut rng, 120, 7, 1.5, 0.5);
    let opts = TrainOptions::default();
    let mut radii = Vec::new();
    for seed in 0..8u64 {
        let perm = Pcg32::seeded(seed).permutation(xs.len());
        let mut m = StreamSvm::new(7, opts);
        for &i in &perm {
            m.observe(&xs[i], ys[i]);
        }
        radii.push(m.radius());
    }
    let min = radii.iter().cloned().fold(f64::MAX, f64::min);
    let max = radii.iter().cloned().fold(f64::MIN, f64::max);
    assert!(max / min < 1.5 + 1e-9, "radius spread {min}..{max} exceeds theory");
}

#[test]
fn degenerate_streams() {
    // all-identical points in the slackless limit: radius stays ~0 (with
    // slack every identical point is still a *distinct* augmented point,
    // so some growth is correct behaviour, not a bug)
    let o = TrainOptions::default().with_c(1e12);
    let mut m = StreamSvm::new(3, o);
    for _ in 0..50 {
        m.observe(&[1.0, 2.0, 3.0], 1.0);
    }
    assert!(m.radius() <= 1e-5, "R = {}", m.radius());

    // with C = 1 the slack axes force growth toward sqrt(s2/2)-ish
    let mut ms = StreamSvm::new(3, TrainOptions::default());
    for _ in 0..50 {
        ms.observe(&[1.0, 2.0, 3.0], 1.0);
    }
    assert!(ms.radius() > 0.5, "slack-driven growth expected, R = {}", ms.radius());
    assert!(ms.radius() < 1.5 * 2.0f64.sqrt());

    // two antipodal points, slackless limit: center at midpoint, R = 1
    let mut m2 = StreamSvm::new(1, o);
    m2.observe(&[1.0], 1.0);
    m2.observe(&[-1.0], 1.0);
    assert!((m2.radius() - 1.0).abs() < 1e-5);
    assert!(m2.weights()[0].abs() < 1e-5);

    // all-zero features: still finite
    let mut m3 = StreamSvm::new(1, TrainOptions::default());
    for i in 0..10 {
        m3.observe(&[0.0], if i % 2 == 0 { 1.0 } else { -1.0 });
    }
    assert!(m3.radius().is_finite());
}

#[test]
fn lookahead_buffer_survives_interleaved_finish() {
    // finish() mid-stream must flush and stay consistent if observation
    // continues afterwards (re-buffering).
    let mut rng = Pcg32::seeded(0xE5);
    let (xs, ys) = gen::labeled_points(&mut rng, 60, 4, 1.5, 0.3);
    let opts = TrainOptions::default().with_lookahead(8);
    let mut m = LookaheadSvm::new(4, opts);
    let mut r_at_mid = 0.0;
    for (k, (x, y)) in xs.iter().zip(&ys).enumerate() {
        m.observe(x, *y);
        if k == 30 {
            m.finish();
            assert_eq!(m.buffered(), 0);
            r_at_mid = m.radius();
        }
    }
    m.finish();
    assert_eq!(m.buffered(), 0);
    assert!(m.radius() >= r_at_mid - 1e-9, "radius shrank after mid-flush");
    assert!(m.examples_seen() == 60);
}

#[test]
fn corrupted_artifact_fails_gracefully() {
    use streamsvm::runtime::Runtime;
    let dir = std::env::temp_dir().join(format!("ssvm_corrupt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), "distance 64 4 bad.hlo.txt\n").unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "HloModule utter garbage (((").unwrap();
    let mut rt = Runtime::open(&dir).expect("manifest parses");
    let w = vec![0.0f32; 4];
    let x = vec![0.0f32; 64 * 4];
    let y = vec![1.0f32; 64];
    let err = rt.distance(&w, &x, &y, 1.0, 1.0, 64, 4);
    assert!(err.is_err(), "corrupt HLO must error, not panic");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_bucket_reports_entry_name() {
    use streamsvm::runtime::Runtime;
    let dir = std::env::temp_dir().join(format!("ssvm_missing_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), "").unwrap();
    let mut rt = Runtime::open(&dir).unwrap();
    let err = rt.predict(&[0.0; 4], &[0.0; 256], 64, 4).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("predict") && msg.contains("make artifacts"), "{msg}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn kernelized_linear_distance_matches_explicit_for_new_points() {
    // For points NOT yet absorbed, the kernelized distance equals the
    // explicit-w distance (both use the no-overlap formula).
    use streamsvm::svm::kernelfn::Kernel;
    use streamsvm::svm::kernelized::KernelStreamSvm;
    let mut rng = Pcg32::seeded(0xE6);
    let (xs, ys) = gen::labeled_points(&mut rng, 60, 3, 1.0, 0.5);
    let opts = TrainOptions::default();
    let mut lin = StreamSvm::new(3, opts);
    let mut ker = KernelStreamSvm::new(Kernel::Linear, opts);
    for (x, y) in xs.iter().zip(&ys) {
        // compare the distances BEFORE observing (probe = unseen point)
        if let Some(ball) = lin.ball() {
            let dl = ball.distance(x, *y, &opts);
            let dk = ker.distance(x, *y);
            assert!((dl - dk).abs() < 1e-6 * dl.max(1.0), "{dl} vs {dk}");
        }
        lin.observe(x, *y);
        ker.observe(x, *y);
    }
}

#[test]
fn multiball_more_balls_never_larger_final_radius_on_clusters() {
    // On well-clustered data, allowing more balls should not *hurt* the
    // final merged radius much (sanity, not a theorem).
    use streamsvm::svm::multiball::{MergePolicy, MultiBallSvm};
    let mut rng = Pcg32::seeded(0xE7);
    // two tight, far-apart clusters
    let mut exs: Vec<Example> = Vec::new();
    for i in 0..100 {
        let c = if i % 2 == 0 { 10.0 } else { -10.0 };
        let x = vec![
            (c + rng.normal() * 0.3) as f32,
            (c + rng.normal() * 0.3) as f32,
        ];
        exs.push(Example::new(x, 1.0));
    }
    let opts = TrainOptions::default().with_c(1e9);
    let r1 = {
        let mut m = MultiBallSvm::new(2, 1, MergePolicy::NearestBall, opts);
        for e in &exs {
            m.observe(&e.x.dense(), e.y);
        }
        m.final_ball().unwrap().r
    };
    let r4 = {
        let mut m = MultiBallSvm::new(2, 4, MergePolicy::NewBallMergeClosest, opts);
        for e in &exs {
            m.observe(&e.x.dense(), e.y);
        }
        m.final_ball().unwrap().r
    };
    assert!(r4 <= r1 * 1.5 + 1e-9, "4 balls {r4} vs 1 ball {r1}");
}
