//! Integration suite for the structure-aware fuzz subsystem: every
//! shipped target runs clean at a bounded case count, the case stream
//! is bit-for-bit deterministic under a fixed seed, the failures
//! directory is created lazily only when a failure exists, and an
//! injected panic is caught, minimized, persisted, and replayed first
//! on the next run (the edr `failurePersistDir` semantics).

use std::cell::RefCell;

use streamsvm::fuzz::{case_bytes, persist, run, run_with, FuzzConfig, Target};
use streamsvm::rng::Pcg32;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ssvm_fuzz_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Every shipped target completes a bounded seeded pass with zero
/// failures — and a clean run leaves no failures directory behind
/// (lazy-creation contract).
#[test]
fn all_targets_run_clean_and_leave_no_failure_dir() {
    let root = tmpdir("clean");
    for (target, cases) in [
        (Target::Http, 300),
        (Target::Json, 300),
        (Target::Codec, 200),
        (Target::Invariants, 30),
    ] {
        let cfg = FuzzConfig { cases, seed: 7, persist_dir: Some(root.clone()) };
        let report = run(target, &cfg).unwrap();
        assert_eq!(report.executed, cases, "{target}");
        assert_eq!(report.replayed, 0, "{target}: nothing persisted yet");
        assert!(
            report.clean(),
            "{target} found failures: {:?} (first: {:?})",
            report.persisted,
            report.sample_failure
        );
    }
    assert!(!root.exists(), "clean runs must not create the failures directory");
}

/// A fixed seed reproduces the whole case stream bit-for-bit, per
/// target; a different seed diverges; the stream is not constant.
#[test]
fn fixed_seed_case_stream_is_bit_identical() {
    for target in Target::ALL {
        let mut distinct = std::collections::HashSet::new();
        let mut diverged = false;
        for i in 0..40u64 {
            let a = case_bytes(target, 42, i);
            let b = case_bytes(target, 42, i);
            assert_eq!(a, b, "{target}: case {i} diverged under the same seed");
            diverged |= a != case_bytes(target, 43, i);
            distinct.insert(a);
        }
        assert!(diverged, "{target}: seed does not influence the stream");
        assert!(distinct.len() > 1, "{target}: case stream is constant");
    }
}

/// The acceptance-criteria loop: a deliberately injected panic in the
/// `json` target's property is caught (no abort), greedily minimized,
/// persisted under `<root>/json/`, and counted as a failure; on the
/// next run the persisted case replays and stays loud until fixed;
/// once fixed, the run is clean again.
#[test]
fn injected_panic_is_caught_minimized_persisted_and_replayed() {
    let root = tmpdir("inject");
    let gen32 = |rng: &mut Pcg32| (0..32).map(|_| rng.next_u32() as u8).collect::<Vec<u8>>();
    let no_fixup = |_: &mut Pcg32, _: &mut Vec<u8>| {};

    // run 1: the property panics on every case (the re-introduced bug)
    let cfg = FuzzConfig { cases: 20, seed: 9, persist_dir: Some(root.clone()) };
    let report = run_with("json", &cfg, gen32, no_fixup, |_bytes| -> Result<(), String> {
        panic!("injected bug");
    })
    .unwrap();
    assert!(!report.clean());
    assert!(report.failures > 0);
    assert!(report.executed <= 20, "persistence cap stops a systemic failure early");
    assert!(!report.persisted.is_empty());
    assert!(
        report.sample_failure.as_deref().unwrap_or("").contains("injected bug"),
        "panic payload must surface: {:?}",
        report.sample_failure
    );
    for p in &report.persisted {
        assert!(p.starts_with(root.join("json")), "{}", p.display());
        assert!(p.is_file());
        // everything reproduces the panic, so minimization bottoms out
        assert_eq!(std::fs::read(p).unwrap(), Vec::<u8>::new());
    }

    // run 2: bug still present — the persisted case replays FIRST and
    // stays loud
    let report = run_with("json", &cfg, gen32, no_fixup, |bytes| -> Result<(), String> {
        if bytes.is_empty() {
            panic!("injected bug");
        }
        Ok(())
    })
    .unwrap();
    assert_eq!(report.replayed, 1, "content-hash naming dedupes the minimized case");
    assert_eq!(report.replay_failures.len(), 1);
    assert!(!report.clean());

    // run 3: bug fixed — replay passes, fresh cases pass, run is clean
    let seen = RefCell::new(Vec::<Vec<u8>>::new());
    let report = run_with("json", &cfg, gen32, no_fixup, |bytes| {
        seen.borrow_mut().push(bytes.to_vec());
        Ok(())
    })
    .unwrap();
    assert_eq!(report.replayed, 1);
    assert!(report.replay_failures.is_empty());
    assert_eq!(report.executed, 20);
    assert!(report.clean());
    // replay-first ordering: the persisted (empty, minimized) case ran
    // before any generated case
    let seen = seen.into_inner();
    assert_eq!(seen.len(), 21);
    assert_eq!(seen[0], Vec::<u8>::new(), "persisted case must replay first");
    std::fs::remove_dir_all(&root).ok();
}

/// Replay order across multiple persisted cases is deterministic
/// (sorted by file name), and persisted cases from one target never
/// leak into another target's run.
#[test]
fn replay_is_sorted_and_target_isolated() {
    let root = tmpdir("order");
    let a = persist::persist(&root, "http", b"case-a").unwrap();
    let b = persist::persist(&root, "http", b"case-b").unwrap();
    let expect: Vec<Vec<u8>> = {
        let mut pairs = vec![(a, b"case-a".to_vec()), (b, b"case-b".to_vec())];
        pairs.sort_by(|x, y| x.0.cmp(&y.0));
        pairs.into_iter().map(|(_, bytes)| bytes).collect()
    };

    let seen = RefCell::new(Vec::<Vec<u8>>::new());
    let cfg = FuzzConfig { cases: 0, seed: 1, persist_dir: Some(root.clone()) };
    let gen4 = |rng: &mut Pcg32| vec![rng.next_u32() as u8; 4];
    let no_fixup = |_: &mut Pcg32, _: &mut Vec<u8>| {};
    let report = run_with("http", &cfg, gen4, no_fixup, |bytes| {
        seen.borrow_mut().push(bytes.to_vec());
        Ok(())
    })
    .unwrap();
    assert_eq!(report.replayed, 2);
    assert_eq!(seen.into_inner(), expect);

    // a different target sees none of them
    let report = run_with("codec", &cfg, gen4, no_fixup, |_| Ok(())).unwrap();
    assert_eq!(report.replayed, 0);
    std::fs::remove_dir_all(&root).ok();
}

/// `Target` round-trips through its CLI string form.
#[test]
fn target_parses_from_cli_strings() {
    for t in Target::ALL {
        let back: Target = t.name().parse().unwrap();
        assert_eq!(back, t);
    }
    assert!("bogus".parse::<Target>().is_err());
}
