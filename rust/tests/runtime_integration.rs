//! Integration tests: the AOT artifacts (L1 Pallas kernels inside L2 JAX
//! graphs, executed via PJRT) against the pure-Rust reference
//! implementations. Skipped with a notice when `artifacts/` is absent
//! (run `make artifacts`).

use streamsvm::coordinator::batcher::Batcher;
use streamsvm::coordinator::pipeline::{train_stream, ExecMode, PipelineConfig};
use streamsvm::data::Example;
use streamsvm::linalg;
use streamsvm::prop::gen;
use streamsvm::rng::Pcg32;
use streamsvm::runtime::{pad_dim, Runtime};
use streamsvm::svm::ball::BallState;
use streamsvm::svm::meb::solve_merge;
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

fn open_runtime() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

fn toy(n: usize, d: usize, seed: u64) -> Vec<Example> {
    let mut rng = Pcg32::seeded(seed);
    let (xs, ys) = gen::labeled_points(&mut rng, n, d, 1.0, 0.6);
    xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect()
}

/// Pad a logical-dim weight vector to the artifact bucket.
fn padded(w: &[f32], d_pad: usize) -> Vec<f32> {
    let mut v = w.to_vec();
    v.resize(d_pad, 0.0);
    v
}

#[test]
fn distance_artifact_matches_rust() {
    let Some(mut rt) = open_runtime() else { return };
    for d in [2usize, 21, 300, 784] {
        let d_pad = pad_dim(d);
        let b = rt.train_block(d_pad).expect("train bucket");
        let exs = toy(b, d, 7 + d as u64);
        let mut blocks = Batcher::new(exs.clone().into_iter(), b, d);
        let block = blocks.next().unwrap().pad(b, d_pad);
        let mut rng = Pcg32::seeded(d as u64);
        let w: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let (xi2, invc) = (0.7f64, 0.5f64);
        let got = rt
            .distance(&padded(&w, d_pad), &block.x, &block.y, xi2 as f32, invc as f32, b, d_pad)
            .unwrap();
        for (i, e) in exs.iter().enumerate() {
            let want = (linalg::sqdist_scaled(&w, &e.x.dense(), e.y) + xi2 + invc).sqrt();
            assert!(
                (got[i] as f64 - want).abs() < 1e-3 * want.max(1.0),
                "d={d} row {i}: artifact {} vs rust {want}",
                got[i]
            );
        }
    }
}

#[test]
fn predict_artifact_matches_rust() {
    let Some(mut rt) = open_runtime() else { return };
    let (d, b) = (300usize, 64usize);
    let d_pad = pad_dim(d);
    let exs = toy(b, d, 11);
    let block = Batcher::new(exs.clone().into_iter(), b, d).next().unwrap().pad(b, d_pad);
    let mut rng = Pcg32::seeded(3);
    let w: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let got = rt.predict(&padded(&w, d_pad), &block.x, b, d_pad).unwrap();
    for (i, e) in exs.iter().enumerate() {
        let want = e.x.view().dot(&w);
        assert!(
            (got[i] as f64 - want).abs() < 1e-3 * want.abs().max(1.0),
            "row {i}: {} vs {want}",
            got[i]
        );
    }
}

#[test]
fn update_artifact_matches_algorithm1() {
    let Some(mut rt) = open_runtime() else { return };
    let d = 21usize;
    let d_pad = pad_dim(d);
    let b = rt.train_block(d_pad).expect("train bucket");
    let exs = toy(b + 40, d, 13); // more than one block's worth; use first block
    let opts = TrainOptions::default().with_c(2.0);

    // rust reference over the block, starting from example 0's init
    let mut ball = BallState::init_view(exs[0].x.view(), exs[0].y, &opts);
    let block = Batcher::new(exs.clone().into_iter(), b, d).next().unwrap().pad(b, d_pad);
    let mut valid = block.valid.clone();
    valid[0] = 0.0; // consumed by init
    let out = rt
        .update(
            &padded(&ball.weights(), d_pad),
            ball.r as f32,
            ball.xi2 as f32,
            &block.x,
            &block.y,
            &valid,
            opts.invc() as f32,
            opts.s2() as f32,
            b,
            d_pad,
        )
        .unwrap();
    let mut updates = 0usize;
    for e in exs.iter().take(b).skip(1) {
        if ball.try_update_view(e.x.view(), e.y, &opts) {
            updates += 1;
        }
    }
    assert_eq!(out.m_added, updates, "update counts diverge");
    assert!((out.r - ball.r).abs() < 1e-3 * ball.r.max(1.0), "r {} vs {}", out.r, ball.r);
    assert!((out.xi2 - ball.xi2).abs() < 1e-3 * ball.xi2.max(1.0));
    let bw = ball.weights();
    for i in 0..d {
        assert!(
            (out.w[i] as f64 - bw[i] as f64).abs() < 2e-3,
            "w[{i}] {} vs {}",
            out.w[i],
            bw[i]
        );
    }
}

#[test]
fn merge_artifact_matches_rust_solver() {
    let Some(mut rt) = open_runtime() else { return };
    let d = 21usize;
    let d_pad = pad_dim(d);
    let l = 16usize;
    let opts = TrainOptions::default().with_c(2.0);
    let exs = toy(l, d, 17);
    let mut rng = Pcg32::seeded(5);
    let w: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let ball = BallState::from_parts(w.clone(), 2.5, 0.6, 3);

    let mut xs = vec![0.0f32; l * d_pad];
    let mut ys = vec![0.0f32; l];
    let valid = vec![1.0f32; l];
    for (i, e) in exs.iter().enumerate() {
        e.x.view().write_into(&mut xs[i * d_pad..i * d_pad + d]);
        ys[i] = e.y;
    }
    let got = rt
        .merge(
            &padded(&w, d_pad),
            ball.r as f32,
            ball.xi2 as f32,
            &xs,
            &ys,
            &valid,
            opts.s2() as f32,
            l,
            d_pad,
        )
        .unwrap();
    let views: Vec<streamsvm::data::FeaturesView> = exs.iter().map(|e| e.x.view()).collect();
    let want = solve_merge(&ball, &views, &ys, &opts);
    // Same Badoiu-Clarkson schedule on both sides → near-identical radii.
    assert!(
        (got.r - want.ball.r).abs() < 1e-3 * want.ball.r.max(1.0),
        "merge r {} vs {}",
        got.r,
        want.ball.r
    );
    assert!((got.xi2 - want.ball.xi2).abs() < 1e-2 * want.ball.xi2.max(1.0));
    let ww = want.ball.weights();
    for i in 0..d {
        assert!(
            (got.w[i] as f64 - ww[i] as f64).abs() < 5e-3,
            "w[{i}] {} vs {}",
            got.w[i],
            ww[i]
        );
    }
}

#[test]
fn pipeline_filter_mode_equals_pure() {
    let Some(mut rt) = open_runtime() else { return };
    let d = 21usize;
    let exs = toy(900, d, 23);
    let base = PipelineConfig {
        train: TrainOptions::default().with_c(2.0),
        queue: 2,
        mode: ExecMode::Pure,
        ..Default::default()
    };
    let pure = train_stream(None, exs.clone().into_iter(), d, base).unwrap();
    let filt = train_stream(
        Some(&mut rt),
        exs.clone().into_iter(),
        d,
        PipelineConfig { mode: ExecMode::Filter, ..base },
    )
    .unwrap();
    assert_eq!(pure.model.num_support(), filt.model.num_support());
    assert!(
        (pure.model.radius() - filt.model.radius()).abs() < 1e-5 * pure.model.radius().max(1.0),
        "radius {} vs {}",
        pure.model.radius(),
        filt.model.radius()
    );
    // the filter must have discarded a meaningful share on-device
    assert!(filt.metrics.survivors < filt.metrics.examples);
    // and weights agree
    let direct = StreamSvm::fit(exs.iter(), d, &base.train);
    for (a, b) in filt.model.weights().unwrap().iter().zip(direct.weights()) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn pipeline_scan_mode_close_to_pure() {
    let Some(mut rt) = open_runtime() else { return };
    let d = 21usize;
    let exs = toy(600, d, 29);
    let base = PipelineConfig {
        train: TrainOptions::default(),
        queue: 2,
        mode: ExecMode::Pure,
        ..Default::default()
    };
    let pure = train_stream(None, exs.clone().into_iter(), d, base).unwrap();
    let scan = train_stream(
        Some(&mut rt),
        exs.clone().into_iter(),
        d,
        PipelineConfig { mode: ExecMode::Scan, ..base },
    )
    .unwrap();
    // Scan runs the whole Algorithm-1 recurrence in f32 on-device vs the
    // f64 Rust path: same update count, radii within float tolerance.
    assert_eq!(pure.model.num_support(), scan.model.num_support());
    assert!(
        (pure.model.radius() - scan.model.radius()).abs() < 1e-3 * pure.model.radius().max(1.0),
        "radius {} vs {}",
        pure.model.radius(),
        scan.model.radius()
    );
}

#[test]
fn pipeline_filter_lookahead_reasonable() {
    let Some(mut rt) = open_runtime() else { return };
    let d = 21usize;
    let exs = toy(800, d, 31);
    let cfg = PipelineConfig {
        train: TrainOptions::default().with_lookahead(10),
        queue: 2,
        mode: ExecMode::Filter,
        ..Default::default()
    };
    let report = train_stream(Some(&mut rt), exs.clone().into_iter(), d, cfg).unwrap();
    assert!(report.metrics.merges >= 1, "no on-device merges happened");
    assert!(report.model.radius() > 0.0);
    // accuracy sanity on its own training data
    let acc = streamsvm::eval::accuracy(&report.model, &exs);
    assert!(acc > 0.7, "acc {acc}");
}
