//! Sparse-path integration suite: the O(nnz) hot path must be
//! tolerance-identical to the dense path on the same stream, round-trip
//! through the `.meb` codec, and compose with the LIBSVM loaders and the
//! serving snapshot.

use streamsvm::data::hashing::{FeatureHasher, HashedStream};
use streamsvm::data::{Example, Features, SparseVec};
use streamsvm::prop::{check, PropConfig};
use streamsvm::rng::Pcg32;
use streamsvm::sketch::codec::MebSketch;
use streamsvm::svm::lookahead::LookaheadSvm;
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

/// Random sparse stream: each row has `nnz` distinct sorted indices with
/// N(0,1) values plus a label-aligned shift on a shared coordinate block.
fn sparse_stream(rng: &mut Pcg32, n: usize, dim: usize, nnz: usize) -> Vec<Example> {
    let mut out = Vec::with_capacity(n);
    let mut taken = vec![false; dim];
    for _ in 0..n {
        let y = rng.label(0.5);
        let mut idx: Vec<u32> = Vec::with_capacity(nnz);
        while idx.len() < nnz {
            let i = rng.below(dim);
            if !taken[i] {
                taken[i] = true;
                idx.push(i as u32);
            }
        }
        for &i in &idx {
            taken[i as usize] = false;
        }
        idx.sort_unstable();
        let val: Vec<f32> = idx
            .iter()
            .map(|&i| {
                let shift = if (i as usize) < dim / 8 { 0.5 * y as f64 } else { 0.0 };
                (rng.normal() + shift) as f32
            })
            .collect();
        out.push(Example::sparse(dim, idx, val, y));
    }
    out
}

fn densify(exs: &[Example]) -> Vec<Example> {
    exs.iter().map(|e| Example::new(e.x.dense().into_owned(), e.y)).collect()
}

#[test]
fn sparse_and_dense_paths_learn_identical_state() {
    // The property of record for the O(nnz) refactor: on the same
    // stream, the sparse and dense paths produce tolerance-identical
    // (w, R, ξ², M).
    check(
        "sparse-dense-equivalence",
        PropConfig { cases: 32, seed: 0x5BA }, // replayable
        |rng, _| {
            let dim = 16 + rng.below(200);
            let nnz = 1 + rng.below(dim.min(24));
            let n = 20 + rng.below(300);
            let opts = TrainOptions::default().with_c(0.5 + rng.uniform() * 4.0);
            let sparse = sparse_stream(rng, n, dim, nnz);
            let dense = densify(&sparse);

            let ms = StreamSvm::fit(sparse.iter(), dim, &opts);
            let md = StreamSvm::fit(dense.iter(), dim, &opts);

            if ms.num_support() != md.num_support() {
                return Err(format!(
                    "M diverged: sparse {} vs dense {}",
                    ms.num_support(),
                    md.num_support()
                ));
            }
            let (bs, bd) = (ms.ball().unwrap(), md.ball().unwrap());
            let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1.0);
            if rel(bs.r, bd.r) > 1e-6 {
                return Err(format!("R diverged: {} vs {}", bs.r, bd.r));
            }
            if rel(bs.xi2, bd.xi2) > 1e-6 {
                return Err(format!("xi2 diverged: {} vs {}", bs.xi2, bd.xi2));
            }
            let (ws, wd) = (ms.weights(), md.weights());
            let scale = wd.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
            for (i, (a, b)) in ws.iter().zip(&wd).enumerate() {
                if (a - b).abs() > 1e-4 * scale {
                    return Err(format!("w[{i}] diverged: {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn sparse_and_dense_lookahead_learn_identical_state() {
    // Algorithm 2 with sparse-buffered survivors: on the same stream the
    // sparse merge path (O(L²·nnz) Gram + scatter-add reconstruction)
    // must match the dense path on (w, R, ξ², M, merges).
    for l in [2usize, 8] {
        check(
            &format!("sparse-dense-lookahead-L{l}"),
            PropConfig { cases: 16, seed: 0x5BC + l as u64 },
            |rng, _| {
                let dim = 16 + rng.below(200);
                let nnz = 1 + rng.below(dim.min(24));
                let n = 30 + rng.below(250);
                let opts = TrainOptions::default()
                    .with_c(0.5 + rng.uniform() * 4.0)
                    .with_lookahead(l);
                let sparse = sparse_stream(rng, n, dim, nnz);
                let dense = densify(&sparse);

                let ms = LookaheadSvm::fit(sparse.iter(), dim, &opts);
                let md = LookaheadSvm::fit(dense.iter(), dim, &opts);

                if ms.num_merges() != md.num_merges() {
                    return Err(format!(
                        "merges diverged: sparse {} vs dense {}",
                        ms.num_merges(),
                        md.num_merges()
                    ));
                }
                if ms.num_support() != md.num_support() {
                    return Err(format!(
                        "M diverged: sparse {} vs dense {}",
                        ms.num_support(),
                        md.num_support()
                    ));
                }
                let (bs, bd) = (ms.ball().unwrap(), md.ball().unwrap());
                let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1.0);
                if rel(bs.r, bd.r) > 1e-6 {
                    return Err(format!("R diverged: {} vs {}", bs.r, bd.r));
                }
                if rel(bs.xi2, bd.xi2) > 1e-6 {
                    return Err(format!("xi2 diverged: {} vs {}", bs.xi2, bd.xi2));
                }
                let (ws, wd) = (ms.weights(), md.weights());
                let scale = wd.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
                for (i, (a, b)) in ws.iter().zip(&wd).enumerate() {
                    if (a - b).abs() > 1e-4 * scale {
                        return Err(format!("w[{i}] diverged: {a} vs {b}"));
                    }
                }
                Ok(())
            },
        );
    }
}

/// Golden vector: the hash mapping is pure integer arithmetic and must
/// be byte-stable across platforms, compilers and releases — a changed
/// bucket or sign silently invalidates every persisted hashed model.
#[test]
fn feature_hashing_golden_vector() {
    let h = FeatureHasher::new(16, 42);
    let hashed = h.hash_pairs(&[0, 3, 7, 123_456_789], &[1.0, 2.0, -1.0, 0.5]);
    let (idx, val) = match &hashed {
        Features::Sparse { dim, v } => {
            assert_eq!(*dim, 16);
            (v.idx.clone(), v.val.clone())
        }
        _ => panic!("hashed output must be sparse"),
    };
    assert_eq!(idx, GOLDEN_IDX, "bucket mapping changed — hash function is not stable");
    assert_eq!(val, GOLDEN_VAL, "sign/accumulation changed — hash function is not stable");
    // and the same inputs through a fresh hasher instance agree
    assert_eq!(FeatureHasher::new(16, 42).hash_pairs(&[0, 3, 7, 123_456_789], &[1.0, 2.0, -1.0, 0.5]), hashed);
}

// Computed once from the splitmix64 definition with an independent
// integer reimplementation: 0→(5,−1), 3→(4,+1), 7→(9,−1),
// 123456789→(5,+1); bucket 5 accumulates −1.0 + 0.5 = −0.5 (a real
// collision, so the accumulation order is pinned too).
const GOLDEN_IDX: [u32; 3] = [4, 5, 9];
const GOLDEN_VAL: [f32; 3] = [2.0, -0.5, 1.0];

#[test]
fn hashed_stream_trains_end_to_end() {
    // A hashed stream of arbitrary-index rows trains a fixed-D model
    // identical to hashing up front, and deterministically across runs.
    let mut rng = Pcg32::seeded(0x5BD);
    let exs = sparse_stream(&mut rng, 200, 5000, 8);
    let h = FeatureHasher::new(256, 7);
    let opts = TrainOptions::default();
    let via_stream: Vec<Example> = HashedStream::new(exs.clone().into_iter(), h).collect();
    let up_front: Vec<Example> = exs.iter().map(|e| h.hash_example(e)).collect();
    assert_eq!(via_stream, up_front);
    let m1 = StreamSvm::fit(via_stream.iter(), 256, &opts);
    let m2 = StreamSvm::fit(up_front.iter(), 256, &opts);
    assert_eq!(m1.weights(), m2.weights());
    assert_eq!(m1.radius().to_bits(), m2.radius().to_bits());
    assert!(m1.num_support() >= 1);
}

#[test]
fn sparse_trained_model_roundtrips_through_meb_codec() {
    let mut rng = Pcg32::seeded(0x5BB);
    let sparse = sparse_stream(&mut rng, 300, 128, 6);
    let opts = TrainOptions::default().with_c(2.0);
    let model = StreamSvm::fit(sparse.iter(), 128, &opts);
    assert!(model.num_support() >= 1);

    let sk = MebSketch::from_model(&model, "sparse-train");
    let back = MebSketch::decode(&sk.encode()).expect("decode");
    assert_eq!(back, sk, "sketch must round-trip bit-exactly");
    let rebuilt = back.to_model();
    assert_eq!(rebuilt.weights(), model.weights());
    assert_eq!(rebuilt.radius().to_bits(), model.radius().to_bits());
    assert_eq!(rebuilt.num_support(), model.num_support());

    // ... and resuming the rebuilt model on more sparse data behaves
    // identically to never having serialized at all.
    let more = sparse_stream(&mut rng, 100, 128, 6);
    let mut a = model;
    let mut b = rebuilt;
    for e in &more {
        a.observe_view(e.x.view(), e.y);
        b.observe_view(e.x.view(), e.y);
    }
    assert_eq!(a.weights(), b.weights());
    assert_eq!(a.radius().to_bits(), b.radius().to_bits());
}

#[test]
fn libsvm_text_trains_sparse_end_to_end() {
    // LIBSVM text → sparse examples → O(nnz) training → finite scores.
    let text = "+1 3:1.0 40:0.5\n-1 1:1.0 7:-0.5\n+1 3:0.8 41:0.25\n-1 2:1.0\n";
    let exs = streamsvm::data::libsvm_format::read_examples(text.as_bytes(), None).unwrap();
    let dim = exs[0].dim();
    assert_eq!(dim, 41); // max 1-based index 41 → 0-based dim 41
    assert!(exs.iter().all(|e| matches!(&e.x, Features::Sparse { .. })));
    let model = StreamSvm::fit(exs.iter(), dim, &TrainOptions::default());
    for e in &exs {
        let s = model.ball().unwrap().score_view(e.x.view());
        assert!(s.is_finite());
    }
}

#[test]
fn sparse_vec_invariants() {
    let v = SparseVec::from_dense(&[0.0, 1.5, 0.0, -2.0, 0.0]);
    assert_eq!(v.nnz(), 2);
    assert_eq!(v.to_dense(5), vec![0.0, 1.5, 0.0, -2.0, 0.0]);
    assert_eq!(v.get(3), -2.0);
    assert_eq!(v.get(0), 0.0);
}
