//! Integration suite for the chunked-ingest refactor: the byte-level
//! chunked parser must be observably identical to the legacy per-line
//! parser on every registry fixture, and the multicore ingest driver
//! must be worker-count invariant up to the documented merge-tree
//! tolerance.

use std::fmt::Write as _;

use streamsvm::coordinator::parallel::{ingest_reader, IngestConfig};
use streamsvm::coordinator::stream::{FileStream, LineStream};
use streamsvm::data::registry::{load_dataset_sized, TABLE1_NAMES};
use streamsvm::data::Example;
use streamsvm::eval::accuracy;
use streamsvm::svm::learner::Variant;
use streamsvm::svm::TrainOptions;

/// Render examples as LIBSVM text exactly the way `gen-data` writes it
/// (`±1` label, 1-based ascending indices, `Display`-formatted values).
fn libsvm_text(exs: &[Example]) -> String {
    let mut out = String::new();
    for e in exs {
        out.push_str(if e.y > 0.0 { "+1" } else { "-1" });
        for (i, v) in e.x.iter_nonzero() {
            write!(out, " {}:{}", i + 1, v).unwrap();
        }
        out.push('\n');
    }
    out
}

fn assert_same_examples(a: &[Example], b: &[Example], fixture: &str) {
    assert_eq!(a.len(), b.len(), "{fixture}: row counts differ");
    for (row, (ea, eb)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            ea.y.to_bits(),
            eb.y.to_bits(),
            "{fixture} row {row}: labels differ"
        );
        assert_eq!(ea.dim(), eb.dim(), "{fixture} row {row}: dims differ");
        let pa: Vec<(usize, u32)> = ea.x.iter_nonzero().map(|(i, v)| (i, v.to_bits())).collect();
        let pb: Vec<(usize, u32)> = eb.x.iter_nonzero().map(|(i, v)| (i, v.to_bits())).collect();
        assert_eq!(pa, pb, "{fixture} row {row}: features differ");
    }
}

/// The tentpole's parsing guarantee on real fixtures: every registry
/// dataset, rendered to the exact text `gen-data` writes, parses to the
/// same `Example` sequence through the chunked byte-level reader as
/// through the legacy per-line reader — labels, indices and values all
/// bit-identical.
#[test]
fn chunked_and_line_parsers_agree_on_every_registry_fixture() {
    for name in TABLE1_NAMES {
        let ds = load_dataset_sized(name, 42, 0.05).unwrap();
        let text = libsvm_text(&ds.train);
        let chunked: Vec<Example> = FileStream::from_reader(text.as_bytes(), ds.dim).collect();
        let lines: Vec<Example> = LineStream::from_reader(text.as_bytes(), ds.dim).collect();
        assert_eq!(chunked.len(), ds.train.len(), "{name}: chunked parser dropped rows");
        assert_same_examples(&chunked, &lines, name);
    }
}

/// Worker-count invariance end to end: the same on-disk bytes ingested
/// with 1 and 8 workers produce models whose test accuracy agrees
/// within 1 percentage point (the CI smoke asserts the same bound
/// through the CLI), and the merged radius dominates every worker's.
#[test]
fn worker_count_moves_accuracy_less_than_one_point() {
    let ds = load_dataset_sized("synthC", 42, 0.5).unwrap();
    let text = libsvm_text(&ds.train);
    let mut accs = Vec::new();
    for workers in [1usize, 8] {
        let rep = ingest_reader(
            text.as_bytes(),
            ds.dim,
            IngestConfig {
                train: TrainOptions::default(),
                variant: Variant::Ball,
                workers,
                // small chunks so 8 workers all actually receive rows
                chunk_bytes: 1 << 12,
                queue: 4,
            },
        )
        .unwrap();
        assert_eq!(rep.rows, ds.train.len(), "workers={workers} dropped rows");
        assert_eq!(rep.skipped, 0, "workers={workers} skipped well-formed rows");
        let merged_r = rep.model.radius();
        for &wr in &rep.worker_radii {
            assert!(
                merged_r >= wr - 1e-9,
                "workers={workers}: merged R={merged_r} below worker R={wr}"
            );
        }
        accs.push(accuracy(&rep.model, &ds.test) * 100.0);
    }
    let diff = (accs[0] - accs[1]).abs();
    assert!(
        diff <= 1.0,
        "worker count moved accuracy {diff:.2} points ({} vs {})",
        accs[0],
        accs[1]
    );
}
