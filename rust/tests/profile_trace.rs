//! Integration: the self-profiling harness and the Chrome-trace export.
//!
//! Two halves. A property test drives [`chrome_trace::render_spans`]
//! with randomized span trees — including the recorded-duration
//! truncation that makes children overhang their parents — and asserts
//! every export passes the strict well-formedness + per-track-nesting
//! checker. An end-to-end test runs the standardized `profile` workload
//! under a live span tree (the `--profile-out` path), checks the
//! `BENCH_obs.json` invariants the CI gate relies on (notably: phase
//! sum within 10% of total wall), and validates the exported trace.

use std::collections::HashMap;

use streamsvm::obs::chrome_trace::{check_chrome_trace, render, render_spans, write_file};
use streamsvm::obs::profiler::{run_profile, ProfileConfig, PHASES};
use streamsvm::obs::span_tree::{self, gen_trace_id, SpanRecord, Trace, PROFILE_SPAN_CAP};
use streamsvm::obs::Value;
use streamsvm::rng::Pcg32;
use streamsvm::server::json::Json;

/// Grow a random subtree under `parent` on one thread track: children
/// open and close sequentially inside the parent's interval, exactly
/// like the real thread-local span stack records them.
fn build_tree(
    rng: &mut Pcg32,
    recs: &mut Vec<SpanRecord>,
    next_id: &mut u64,
    parent: u64,
    thread: u64,
    clock: &mut u64,
    depth: usize,
) {
    let kids = rng.below(4);
    for _ in 0..kids {
        let id = *next_id;
        *next_id += 1;
        *clock += rng.below(3) as u64; // gap before the child opens
        let start = *clock;
        if depth < 4 {
            build_tree(rng, recs, next_id, id, thread, clock, depth + 1);
        }
        *clock += rng.below(5) as u64; // tail work inside the child
        let fields = if rng.below(3) == 0 { vec![("i", Value::U64(id))] } else { vec![] };
        recs.push(SpanRecord {
            id,
            parent,
            target: "prop",
            name: "node",
            start_us: start,
            dur_us: *clock - start,
            thread,
            fields,
        });
    }
}

#[test]
fn chrome_trace_export_nests_for_randomized_span_trees() {
    let mut checked = 0usize;
    for seed in 0..40u64 {
        let mut rng = Pcg32::seeded(0xC0FFEE + seed);
        let mut recs = Vec::new();
        let mut next_id = 2u64;
        let threads = 1 + rng.below(3) as u64;
        let mut max_end = 0u64;
        for th in 0..threads {
            let mut clock = rng.below(4) as u64;
            build_tree(&mut rng, &mut recs, &mut next_id, 1, th, &mut clock, 0);
            max_end = max_end.max(clock);
        }
        recs.push(SpanRecord {
            id: 1,
            parent: 0,
            target: "prop",
            name: "root",
            start_us: 0,
            dur_us: max_end,
            thread: 0,
            fields: vec![],
        });

        // Simulate the independent µs truncation of each span's recorded
        // duration: ends move left by up to 1µs, so a child can overhang
        // its (shrunk) parent — the exact overhang the exporter clamps.
        let end_of: HashMap<u64, u64> =
            recs.iter().map(|r| (r.id, r.start_us + r.dur_us)).collect();
        for r in &mut recs {
            if r.dur_us > 0 && rng.below(2) == 1 {
                r.dur_us -= 1;
            }
        }

        let json = render_spans(&recs);
        let n = check_chrome_trace(&json)
            .unwrap_or_else(|e| panic!("seed {seed}: invalid export: {e}\n{json}"));
        assert_eq!(n, recs.len(), "seed {seed}: event count");
        // sanity on the generator itself: parents exist for every span
        for r in &recs {
            assert!(r.parent == 0 || end_of.contains_key(&r.parent), "orphan span");
        }
        checked += n;
    }
    assert!(checked > 100, "generator degenerated: only {checked} events across all seeds");
}

#[test]
fn profile_workload_reports_phases_and_exports_a_chrome_trace() {
    let cfg = ProfileConfig { rows: 400, dim: 256, nnz: 8, hash_dim: 64, ..Default::default() };

    // The `profile --profile-out` path: the whole workload records into
    // one span tree through the profile fallback.
    streamsvm::obs::set_tracing(true);
    let t0 = streamsvm::obs::recorder::now_us();
    let trace = Trace::start(gen_trace_id(), PROFILE_SPAN_CAP);
    span_tree::set_profile_trace(Some(&trace));
    let report = run_profile(&cfg);
    span_tree::set_profile_trace(None);
    streamsvm::obs::set_tracing(false);
    let now = streamsvm::obs::recorder::now_us();
    trace.finish_root("profile", "run", t0, now.saturating_sub(t0), vec![]);

    // BENCH_obs.json invariants the CI gate keys on.
    let doc = report.to_json();
    let j = Json::parse(&doc).unwrap_or_else(|e| panic!("invalid BENCH_obs.json: {e}\n{doc}"));
    assert_eq!(j.get("rows").and_then(|v| v.as_f64()), Some(400.0));
    assert!(j.get("rows_per_s").and_then(|v| v.as_f64()).unwrap() > 0.0);
    let phases = j.get("phases").expect("phases object");
    for p in PHASES {
        assert!(phases.get(p).and_then(|v| v.as_f64()).unwrap() > 0.0, "phase {p} missing");
    }
    let variants = j.get("variants").expect("variants object");
    for v in ["streamsvm", "lookahead", "kernelized", "ellipsoid", "multiball"] {
        assert!(variants.get(v).and_then(|x| x.as_f64()).unwrap() > 0.0, "variant {v} missing");
    }
    // the acceptance bound: phase sum within 10% of total wall
    let total = j.get("total_s").and_then(|v| v.as_f64()).unwrap();
    let sum = j.get("phase_sum_s").and_then(|v| v.as_f64()).unwrap();
    assert!(sum <= total * 1.000001, "phase sum {sum} exceeds total {total}");
    assert!(sum >= 0.90 * total, "phase sum {sum} covers <90% of total {total}");

    // The exported trace is well-formed, nested, and carries the run:
    // root + six phases + the variants group + five variant fits.
    let json = render(&trace);
    let n = check_chrome_trace(&json).unwrap_or_else(|e| panic!("invalid chrome trace: {e}"));
    assert!(n >= 13, "only {n} events exported");
    for name in ["\"parse\"", "\"merge\"", "\"republish\"", "\"multiball\"", "\"run\""] {
        assert!(json.contains(name), "export lost {name}");
    }

    // ... and the file form `--profile-out` writes round-trips.
    let path = std::env::temp_dir().join(format!("ssvm_profile_{}.json", std::process::id()));
    write_file(&trace, path.to_str().unwrap()).unwrap();
    let from_disk = std::fs::read_to_string(&path).unwrap();
    assert_eq!(check_chrome_trace(&from_disk).unwrap(), n);
    std::fs::remove_file(&path).ok();
}
