//! Cross-variant conformance: every StreamSVM variant, one stream, one
//! set of invariants.
//!
//! The paper's guarantees (radius ratio, SV-count bound, one-pass
//! memory) all rest on the same geometric laws, so every variant —
//! `StreamSvm` (Algorithm 1), `LookaheadSvm` (Algorithm 2),
//! `MultiBallSvm` (§4.3), `KernelStreamSvm` (§4.2) and `EllipsoidSvm`
//! (§6.2) — must agree on them when driven with identical example
//! streams, sparse and dense alike:
//!
//! * **Radius monotonicity** — the enclosing radius never shrinks.
//! * **Convex-coefficient laws** — the kernelized α stay a signed convex
//!   combination (`Σ|α| = 1`, every |α| ≤ 1); the explicit centers stay
//!   finite convex blends (finite w, ξ² ∈ (0, s²]).
//! * **Reduction anchors** — the linear-kernel `KernelStreamSvm` and the
//!   isotropic-metric `EllipsoidSvm` are Algorithm 1 in disguise and
//!   must match `BallState`'s `(w, R, ξ²)` to tolerance, with identical
//!   update decisions.

use streamsvm::data::{Example, Features};
use streamsvm::eval::Classifier;
use streamsvm::prop::{check, gen, PropConfig};
use streamsvm::rng::Pcg32;
use streamsvm::sketch::codec::MebSketch;
use streamsvm::svm::ellipsoid::EllipsoidSvm;
use streamsvm::svm::kernelfn::Kernel;
use streamsvm::svm::kernelized::KernelStreamSvm;
use streamsvm::svm::learner::{AnyLearner, StreamLearner, Variant, DEFAULT_MAX_BALLS};
use streamsvm::svm::lookahead::LookaheadSvm;
use streamsvm::svm::multiball::{MergePolicy, MultiBallSvm};
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

/// One generated conformance stream: dense rows plus their sparse twins.
struct Stream {
    dense: Vec<Vec<f32>>,
    sparse: Vec<Features>,
    ys: Vec<f32>,
    dim: usize,
}

fn gen_stream(rng: &mut Pcg32, n: usize) -> Stream {
    let dim = gen::dim(rng);
    let (dense, ys) = gen::labeled_points(rng, n, dim, 1.2, 0.4);
    let sparse = dense.iter().map(|x| Features::Dense(x.clone()).to_sparse()).collect();
    Stream { dense, sparse, ys, dim }
}

/// Drive `step(i)` (observe example `i`, return the current radius) over
/// the stream, checking radius monotonicity after every example.
fn check_monotone(
    name: &str,
    n: usize,
    mut step: impl FnMut(usize) -> f64,
) -> Result<(), String> {
    let mut prev = 0.0;
    for i in 0..n {
        let r = step(i);
        if !r.is_finite() {
            return Err(format!("{name}: radius went non-finite at example {i}"));
        }
        if r < prev - 1e-9 {
            return Err(format!("{name}: radius shrank {prev} -> {r} at example {i}"));
        }
        prev = r;
    }
    Ok(())
}

#[test]
fn all_variants_radius_monotone_and_coefficients_convex() {
    check(
        "conformance-monotone-convex",
        PropConfig { cases: 24, seed: 0xC04F }, // 5 variants × 2 representations per case
        |rng, case| {
            let st = gen_stream(rng, 48);
            let use_sparse = case % 2 == 0;
            let opts = TrainOptions::default().with_c(0.5 + rng.uniform() * 4.0);
            let feed = |i: usize| st.sparse[i].view();
            let n = st.ys.len();

            // Algorithm 1
            let mut a1 = StreamSvm::new(st.dim, opts);
            check_monotone("stream", n, |i| {
                if use_sparse {
                    a1.observe_view(feed(i), st.ys[i]);
                } else {
                    a1.observe(&st.dense[i], st.ys[i]);
                }
                a1.radius()
            })?;

            // Algorithm 2 (lookahead): monotone through the merge solves
            let l = 2 + rng.below(6);
            let mut a2 = LookaheadSvm::new(st.dim, opts.with_lookahead(l));
            check_monotone("lookahead", n, |i| {
                if use_sparse {
                    a2.observe_view(feed(i), st.ys[i]);
                } else {
                    a2.observe(&st.dense[i], st.ys[i]);
                }
                a2.radius()
            })?;
            let before_finish = a2.radius();
            a2.finish();
            if a2.radius() < before_finish - 1e-9 {
                return Err("lookahead finish shrank the radius".into());
            }

            // Kernelized (linear): radius + convex coefficients
            let mut ker = KernelStreamSvm::new(Kernel::Linear, opts);
            check_monotone("kernelized", n, |i| {
                if use_sparse {
                    ker.observe_view(feed(i), st.ys[i]);
                } else {
                    ker.observe(&st.dense[i], st.ys[i]);
                }
                ker.radius()
            })?;
            let sum_abs: f64 = ker.coefficients().iter().map(|a| a.abs()).sum();
            if (sum_abs - 1.0).abs() > 1e-9 {
                return Err(format!("kernelized Σ|α| = {sum_abs}"));
            }
            if !ker.coefficients().iter().all(|a| a.abs() <= 1.0 + 1e-12) {
                return Err("kernelized |α| > 1".into());
            }

            // Ellipsoid (isotropic metric)
            let mut ell = EllipsoidSvm::isotropic(st.dim, opts);
            check_monotone("ellipsoid", n, |i| {
                if use_sparse {
                    ell.observe_view(feed(i), st.ys[i]);
                } else {
                    ell.observe(&st.dense[i], st.ys[i]);
                }
                ell.radius()
            })?;
            if !(ell.xi2() > 0.0 && ell.xi2() <= opts.s2() + 1e-12) {
                return Err(format!("ellipsoid ξ² = {} outside (0, s²]", ell.xi2()));
            }

            // Multiball: bounded ball count, finite merged final ball
            // whose radius dominates nothing smaller than zero (its
            // per-ball radii are not exposed; the merge-enclosure law is
            // pinned by the multiball unit suite).
            let mut mb = MultiBallSvm::new(st.dim, 3, MergePolicy::NewBallMergeClosest, opts);
            for i in 0..n {
                if use_sparse {
                    mb.observe_view(feed(i), st.ys[i]);
                } else {
                    mb.observe(&st.dense[i], st.ys[i]);
                }
                if mb.num_balls() > 3 {
                    return Err(format!("multiball exceeded L: {}", mb.num_balls()));
                }
            }
            let fb = mb.final_ball().ok_or("multiball produced no final ball")?;
            if !fb.r.is_finite() || fb.r < 0.0 {
                return Err(format!("multiball final radius {}", fb.r));
            }
            if !fb.weights().iter().all(|w| w.is_finite()) {
                return Err("multiball final center non-finite".into());
            }
            Ok(())
        },
    );
}

/// The reduction anchors: linear-kernelized and isotropic-ellipsoid are
/// Algorithm 1 in different clothes. Same update decisions, same
/// `(w, R, ξ²)` to tolerance — sparse and dense inputs both.
#[test]
fn linear_kernelized_and_isotropic_ellipsoid_match_ballstate() {
    check(
        "conformance-reduction-anchors",
        PropConfig { cases: 32, seed: 0xBA11 },
        |rng, case| {
            let st = gen_stream(rng, 56);
            let use_sparse = case % 2 == 0;
            let opts = TrainOptions::default().with_c(0.5 + rng.uniform() * 4.0);
            let mut ball = StreamSvm::new(st.dim, opts);
            let mut ker = KernelStreamSvm::new(Kernel::Linear, opts);
            let mut ell = EllipsoidSvm::isotropic(st.dim, opts);
            for i in 0..st.ys.len() {
                let (ub, uk, ue) = if use_sparse {
                    let v = st.sparse[i].view();
                    (
                        ball.observe_view(v, st.ys[i]),
                        ker.observe_view(v, st.ys[i]),
                        ell.observe_view(v, st.ys[i]),
                    )
                } else {
                    (
                        ball.observe(&st.dense[i], st.ys[i]),
                        ker.observe(&st.dense[i], st.ys[i]),
                        ell.observe(&st.dense[i], st.ys[i]),
                    )
                };
                if ub != uk || ub != ue {
                    return Err(format!(
                        "update decisions diverged at example {i}: ball {ub}, kernel {uk}, ellipsoid {ue}"
                    ));
                }
            }
            let b = ball.ball().ok_or("ball never initialized")?;

            // R
            let rtol = 1e-6 * b.r.max(1.0);
            if (ker.radius() - b.r).abs() > rtol {
                return Err(format!("kernelized R {} vs ball {}", ker.radius(), b.r));
            }
            if (ell.radius() - b.r).abs() > 1e-12 * b.r.max(1.0) {
                return Err(format!("ellipsoid R {} vs ball {}", ell.radius(), b.r));
            }
            // ξ² (the kernelized recurrence compounds β through its own
            // float path — numpy mirror puts the worst drift near 2e-9,
            // so the bound matches R's rather than demanding bit-parity)
            if (ker.xi2() - b.xi2).abs() > 1e-6 * b.xi2.max(1.0) {
                return Err(format!("kernelized ξ² {} vs ball {}", ker.xi2(), b.xi2));
            }
            if (ell.xi2() - b.xi2).abs() > 1e-12 * b.xi2.max(1.0) {
                return Err(format!("ellipsoid ξ² {} vs ball {}", ell.xi2(), b.xi2));
            }
            // w: the ellipsoid materializes its center; the kernelized
            // center is probed on the basis vectors (linear kernel ⇒
            // f(e_j) = w_j exactly).
            let w = ball.weights();
            let we = ell.weights();
            for j in 0..st.dim {
                if (w[j] - we[j]).abs() > 1e-5 * w[j].abs().max(1.0) {
                    return Err(format!("ellipsoid w[{j}] {} vs ball {}", we[j], w[j]));
                }
                let mut e = vec![0.0f32; st.dim];
                e[j] = 1.0;
                let wk = ker.score(&e);
                if (w[j] as f64 - wk).abs() > 1e-4 * (w[j].abs() as f64).max(1.0) {
                    return Err(format!("kernelized w[{j}] {wk} vs ball {}", w[j]));
                }
            }
            // M (support counts agree: decisions were identical)
            if ball.num_support() != ker.num_support()
                || ball.num_support() != ell.num_support()
            {
                return Err(format!(
                    "M diverged: ball {}, kernel {}, ellipsoid {}",
                    ball.num_support(),
                    ker.num_support(),
                    ell.num_support()
                ));
            }
            Ok(())
        },
    );
}

/// Sparse and dense physical representations of the same logical stream
/// must produce tolerance-identical state in every variant.
#[test]
fn sparse_and_dense_trajectories_agree_across_variants() {
    check(
        "conformance-sparse-dense",
        PropConfig { cases: 16, seed: 0x5A55 },
        |rng, _| {
            let st = gen_stream(rng, 48);
            let opts = TrainOptions::default();
            let n = st.ys.len();

            let mut a1d = StreamSvm::new(st.dim, opts);
            let mut a1s = StreamSvm::new(st.dim, opts);
            let la = opts.with_lookahead(4);
            let mut a2d = LookaheadSvm::new(st.dim, la);
            let mut a2s = LookaheadSvm::new(st.dim, la);
            let mut kd = KernelStreamSvm::new(Kernel::Rbf { gamma: 0.3 }, opts);
            let mut ks = KernelStreamSvm::new(Kernel::Rbf { gamma: 0.3 }, opts);
            let mut ed = EllipsoidSvm::new(st.dim, opts);
            let mut es = EllipsoidSvm::new(st.dim, opts);
            for i in 0..n {
                let (x, v, y) = (&st.dense[i], st.sparse[i].view(), st.ys[i]);
                a1d.observe(x, y);
                a1s.observe_view(v, y);
                a2d.observe(x, y);
                a2s.observe_view(v, y);
                if kd.observe(x, y) != ks.observe_view(v, y) {
                    return Err(format!("kernelized decisions diverged at {i}"));
                }
                if ed.observe(x, y) != es.observe_view(v, y) {
                    return Err(format!("ellipsoid decisions diverged at {i}"));
                }
            }
            a2d.finish();
            a2s.finish();
            let pairs: [(&str, f64, f64); 4] = [
                ("stream", a1d.radius(), a1s.radius()),
                ("lookahead", a2d.radius(), a2s.radius()),
                ("kernelized", kd.radius(), ks.radius()),
                ("ellipsoid", ed.radius(), es.radius()),
            ];
            for (name, rd, rs) in pairs {
                if (rd - rs).abs() > 1e-6 * rd.max(1.0) {
                    return Err(format!("{name}: R diverged {rd} vs {rs}"));
                }
            }
            if a1d.num_support() != a1s.num_support()
                || a2d.num_support() != a2s.num_support()
                || kd.num_support() != ks.num_support()
                || ed.num_support() != es.num_support()
            {
                return Err("support counts diverged between representations".into());
            }
            for (a, b) in ed.axes().iter().zip(es.axes()) {
                if (a - b).abs() > 1e-9 * a.max(1.0) {
                    return Err(format!("ellipsoid metric diverged {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

/// The same laws through the unified [`AnyLearner`] surface: enum
/// dispatch must be a zero-cost veneer. Radius monotonicity holds when
/// driven generically, and the final state — radius, probe scores,
/// support count — is *bit-identical* to the concrete variant driven
/// directly with the identical stream.
#[test]
fn any_learner_is_bit_identical_to_direct_variants() {
    check(
        "conformance-any-learner",
        PropConfig { cases: 10, seed: 0xA17E },
        |rng, _| {
            let st = gen_stream(rng, 40);
            // lookahead > 1 so AnyLearner::new keeps it verbatim and the
            // concrete twin sees the exact same options
            let opts = TrainOptions::default()
                .with_c(0.5 + rng.uniform() * 4.0)
                .with_lookahead(2 + rng.below(5));
            let n = st.ys.len();
            let probes: Vec<&[f32]> = st.dense.iter().take(8).map(|v| v.as_slice()).collect();
            for variant in Variant::ALL {
                // concrete twin, constructed exactly as AnyLearner::new does
                let (r_direct, m_direct, s_direct): (f64, usize, Vec<u64>) = match variant {
                    Variant::Ball => {
                        let mut m = StreamSvm::new(st.dim, opts);
                        for i in 0..n {
                            m.observe_view(st.sparse[i].view(), st.ys[i]);
                        }
                        StreamLearner::finish(&mut m);
                        (
                            m.radius(),
                            m.num_support(),
                            probes.iter().map(|p| Classifier::score(&m, p).to_bits()).collect(),
                        )
                    }
                    Variant::Lookahead => {
                        let mut m = LookaheadSvm::new(st.dim, opts);
                        for i in 0..n {
                            m.observe_view(st.sparse[i].view(), st.ys[i]);
                        }
                        m.finish();
                        (
                            m.radius(),
                            m.num_support(),
                            probes.iter().map(|p| Classifier::score(&m, p).to_bits()).collect(),
                        )
                    }
                    Variant::Kernelized => {
                        let mut m = KernelStreamSvm::new(Kernel::Linear, opts);
                        for i in 0..n {
                            m.observe_view(st.sparse[i].view(), st.ys[i]);
                        }
                        StreamLearner::finish(&mut m);
                        (
                            m.radius(),
                            m.num_support(),
                            probes.iter().map(|p| Classifier::score(&m, p).to_bits()).collect(),
                        )
                    }
                    Variant::Ellipsoid => {
                        let mut m = EllipsoidSvm::new(st.dim, opts);
                        for i in 0..n {
                            m.observe_view(st.sparse[i].view(), st.ys[i]);
                        }
                        StreamLearner::finish(&mut m);
                        (
                            m.radius(),
                            m.num_support(),
                            probes.iter().map(|p| Classifier::score(&m, p).to_bits()).collect(),
                        )
                    }
                    Variant::Multiball => {
                        let mut m = MultiBallSvm::new(
                            st.dim,
                            DEFAULT_MAX_BALLS,
                            MergePolicy::NearestBall,
                            opts,
                        );
                        for i in 0..n {
                            m.observe_view(st.sparse[i].view(), st.ys[i]);
                        }
                        StreamLearner::finish(&mut m);
                        (
                            StreamLearner::radius(&m),
                            m.num_support(),
                            probes.iter().map(|p| Classifier::score(&m, p).to_bits()).collect(),
                        )
                    }
                };
                // generic drive, radius law checked after every example
                let mut any = AnyLearner::new(variant, st.dim, opts);
                check_monotone(variant.name(), n, |i| {
                    any.observe_view(st.sparse[i].view(), st.ys[i]);
                    any.radius()
                })?;
                let before = any.radius();
                any.finish();
                if any.radius() < before - 1e-9 {
                    return Err(format!("{variant}: finish shrank the radius"));
                }
                if any.radius().to_bits() != r_direct.to_bits() {
                    return Err(format!(
                        "{variant}: AnyLearner R {} != direct {r_direct}",
                        any.radius()
                    ));
                }
                if any.num_support() != m_direct {
                    return Err(format!(
                        "{variant}: AnyLearner M {} != direct {m_direct}",
                        any.num_support()
                    ));
                }
                for (j, (p, want)) in probes.iter().zip(&s_direct).enumerate() {
                    if any.score(p).to_bits() != *want {
                        return Err(format!("{variant}: probe {j} score diverged"));
                    }
                }
                if any.examples_seen() != n {
                    return Err(format!(
                        "{variant}: examples_seen {} != {n}",
                        any.examples_seen()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Serialization is part of the conformance surface: every variant must
/// survive the v4 `.meb` codec — encode, decode, [`MebSketch::to_learner`]
/// — with its variant tag intact and *bit-identical* radius and probe
/// scores (what the serve snapshot/restore flow relies on). The
/// non-linear RBF kernelized learner rides along: its sketch has no
/// summary ball, only the exact-state section.
#[test]
fn meb_round_trip_restores_every_variant_bit_identically() {
    check(
        "conformance-meb-round-trip",
        PropConfig { cases: 10, seed: 0x0DEC },
        |rng, _| {
            let st = gen_stream(rng, 44);
            let opts = TrainOptions::default()
                .with_c(0.5 + rng.uniform() * 4.0)
                .with_lookahead(2 + rng.below(5));
            let n = st.ys.len();
            let mut learners: Vec<AnyLearner> =
                Variant::ALL.iter().map(|&v| AnyLearner::new(v, st.dim, opts)).collect();
            learners.push(AnyLearner::with_kernel(
                Variant::Kernelized,
                st.dim,
                opts,
                Kernel::Rbf { gamma: 0.25 },
            ));
            for m in &mut learners {
                for i in 0..n {
                    m.observe_view(st.sparse[i].view(), st.ys[i]);
                }
                m.finish();
            }
            for m in &learners {
                let v = m.variant();
                let sk = MebSketch::from_learner(m, "conformance");
                let bytes = sk.encode();
                let back =
                    MebSketch::decode(&bytes).map_err(|e| format!("{v}: decode: {e}"))?;
                if back.variant != v {
                    return Err(format!("{v}: round-trip variant tag became {}", back.variant));
                }
                let restored =
                    back.to_learner().map_err(|e| format!("{v}: to_learner: {e}"))?;
                if restored.variant() != v {
                    return Err(format!("{v}: restored as {}", restored.variant()));
                }
                if restored.examples_seen() != m.examples_seen() {
                    return Err(format!(
                        "{v}: seen {} != {}",
                        restored.examples_seen(),
                        m.examples_seen()
                    ));
                }
                if restored.radius().to_bits() != m.radius().to_bits() {
                    return Err(format!(
                        "{v}: restored R {} != {} (not bit-identical)",
                        restored.radius(),
                        m.radius()
                    ));
                }
                for (j, x) in st.dense.iter().take(8).enumerate() {
                    if restored.score(x).to_bits() != m.score(x).to_bits() {
                        return Err(format!("{v}: probe {j} score diverged after round-trip"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The validated entry points reject malformed input identically across
/// variants — same error classes, no state consumed (the PR-4
/// robustness contract, now covering the kernelized and ellipsoid
/// variants too).
#[test]
fn try_observe_rejections_are_uniform_across_variants() {
    use streamsvm::data::FeaturesView;
    use streamsvm::error::Error;

    let opts = TrainOptions::default();
    let good = [1.0f32, -2.0, 0.5];
    let nan = [1.0f32, f32::NAN, 0.5];
    let short = [1.0f32, 2.0];

    // each closure returns (err on wrong-dim, err on NaN, err on bad label)
    let mut a1 = StreamSvm::new(3, opts);
    let mut a2 = LookaheadSvm::new(3, opts.with_lookahead(4));
    let mut mb = MultiBallSvm::new(3, 2, MergePolicy::NearestBall, opts);
    let mut ker = KernelStreamSvm::new(Kernel::Linear, opts);
    let mut ell = EllipsoidSvm::new(3, opts);
    // pin the kernelized dimension with one valid example first
    assert!(ker.try_observe(FeaturesView::Dense(&good), 1.0).unwrap());

    macro_rules! expect {
        ($call:expr, $variant:path, $who:literal) => {{
            let err = $call.unwrap_err();
            assert!(matches!(err, $variant(_)), "{}: {err}", $who);
        }};
    }
    expect!(a1.try_observe(FeaturesView::Dense(&short), 1.0), Error::Config, "stream");
    expect!(a2.try_observe(FeaturesView::Dense(&short), 1.0), Error::Config, "lookahead");
    expect!(mb.try_observe(FeaturesView::Dense(&short), 1.0), Error::Config, "multiball");
    expect!(ker.try_observe(FeaturesView::Dense(&short), 1.0), Error::Config, "kernelized");
    expect!(ell.try_observe(FeaturesView::Dense(&short), 1.0), Error::Config, "ellipsoid");

    expect!(a1.try_observe(FeaturesView::Dense(&nan), 1.0), Error::Data, "stream");
    expect!(a2.try_observe(FeaturesView::Dense(&nan), 1.0), Error::Data, "lookahead");
    expect!(mb.try_observe(FeaturesView::Dense(&nan), 1.0), Error::Data, "multiball");
    expect!(ker.try_observe(FeaturesView::Dense(&nan), 1.0), Error::Data, "kernelized");
    expect!(ell.try_observe(FeaturesView::Dense(&nan), 1.0), Error::Data, "ellipsoid");

    expect!(a1.try_observe(FeaturesView::Dense(&good), 0.5), Error::Data, "stream");
    expect!(a2.try_observe(FeaturesView::Dense(&good), 0.5), Error::Data, "lookahead");
    expect!(mb.try_observe(FeaturesView::Dense(&good), 0.5), Error::Data, "multiball");
    expect!(ker.try_observe(FeaturesView::Dense(&good), 0.5), Error::Data, "kernelized");
    expect!(ell.try_observe(FeaturesView::Dense(&good), 0.5), Error::Data, "ellipsoid");

    // rejects consumed no stream position anywhere
    assert_eq!(a1.examples_seen(), 0);
    assert_eq!(a2.examples_seen(), 0);
    assert_eq!(mb.examples_seen(), 0);
    assert_eq!(ker.examples_seen(), 1);
    assert_eq!(ell.examples_seen(), 0);

    // and valid input still flows everywhere
    assert!(a1.try_observe(FeaturesView::Dense(&good), 1.0).is_ok());
    assert!(a2.try_observe(FeaturesView::Dense(&good), 1.0).is_ok());
    assert!(mb.try_observe(FeaturesView::Dense(&good), 1.0).is_ok());
    assert!(ker.try_observe(FeaturesView::Dense(&good), -1.0).is_ok());
    assert!(ell.try_observe(FeaturesView::Dense(&good), 1.0).is_ok());

    // the identical contract holds through the unified surface
    for v in Variant::ALL {
        let mut any = AnyLearner::new(v, 3, opts);
        any.try_observe(FeaturesView::Dense(&good), 1.0).unwrap();
        let err = any.try_observe(FeaturesView::Dense(&short), 1.0).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{v}: wrong-dim gave {err}");
        let err = any.try_observe(FeaturesView::Dense(&nan), 1.0).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{v}: NaN gave {err}");
        let err = any.try_observe(FeaturesView::Dense(&good), 0.5).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{v}: bad label gave {err}");
        assert_eq!(any.examples_seen(), 1, "{v}: rejections consumed stream positions");
    }
}

/// End-to-end sanity on a learnable stream: every variant separates the
/// same two-Gaussian data well (the conformance suite is about shared
/// laws, not about one variant quietly degenerating).
#[test]
fn every_variant_learns_the_same_separable_stream() {
    let mut rng = Pcg32::seeded(0x1EA2);
    let (xs, ys) = gen::labeled_points(&mut rng, 1500, 8, 1.0, 1.2);
    let exs: Vec<Example> =
        xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect();
    let opts = TrainOptions::default();
    let a1 = StreamSvm::fit(exs.iter(), 8, &opts);
    let a2 = LookaheadSvm::fit(exs.iter(), 8, &opts.with_lookahead(8));
    let mb = MultiBallSvm::fit(exs.iter(), 8, 3, MergePolicy::NearestBall, &opts);
    let ker = KernelStreamSvm::fit(exs.iter(), Kernel::Linear, &opts);
    let ell = EllipsoidSvm::fit(exs.iter(), 8, &opts);
    for (name, acc) in [
        ("stream", streamsvm::eval::accuracy(&a1, &exs)),
        ("lookahead", streamsvm::eval::accuracy(&a2, &exs)),
        ("multiball", streamsvm::eval::accuracy(&mb, &exs)),
        ("kernelized", streamsvm::eval::accuracy(&ker, &exs)),
        ("ellipsoid", streamsvm::eval::accuracy(&ell, &exs)),
    ] {
        assert!(acc > 0.85, "{name} acc = {acc:.3}");
    }
}
