//! Cross-variant conformance: every StreamSVM variant, one stream, one
//! set of invariants.
//!
//! The laws themselves — radius monotonicity, convex-coefficient laws,
//! the reduction anchors, sparse/dense agreement, codec round-trips,
//! the `try_observe` rejection contract — live in
//! [`streamsvm::fuzz::laws`] as reusable property functions, shared
//! with the randomized fuzz harness (`fuzz --target invariants`). This
//! suite drives them over the seeded two-Gaussian case distribution
//! and adds the checks that need concrete variant types (bit-identity
//! against direct construction, RBF kernels, anisotropic metrics).

use streamsvm::data::Example;
use streamsvm::eval::Classifier;
use streamsvm::fuzz::laws;
use streamsvm::prop::{check, gen, PropConfig};
use streamsvm::rng::Pcg32;
use streamsvm::svm::ellipsoid::EllipsoidSvm;
use streamsvm::svm::kernelfn::Kernel;
use streamsvm::svm::kernelized::KernelStreamSvm;
use streamsvm::svm::learner::{AnyLearner, StreamLearner, Variant, DEFAULT_MAX_BALLS};
use streamsvm::svm::lookahead::LookaheadSvm;
use streamsvm::svm::multiball::{MergePolicy, MultiBallSvm};
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

#[test]
fn all_variants_radius_monotone_and_coefficients_convex() {
    check(
        "conformance-monotone-convex",
        PropConfig { cases: 24, seed: 0xC04F }, // 5 variants × 2 representations per case
        |rng, case| {
            let st = laws::gen_stream(rng, 48);
            let opts = TrainOptions::default().with_c(0.5 + rng.uniform() * 4.0);
            laws::monotone_and_convex(&st, opts, case % 2 == 0, 2 + rng.below(6))
        },
    );
}

/// The reduction anchors: linear-kernelized and isotropic-ellipsoid are
/// Algorithm 1 in different clothes. Same update decisions, same
/// `(w, R, ξ²)` to tolerance — sparse and dense inputs both.
#[test]
fn linear_kernelized_and_isotropic_ellipsoid_match_ballstate() {
    check(
        "conformance-reduction-anchors",
        PropConfig { cases: 32, seed: 0xBA11 },
        |rng, case| {
            let st = laws::gen_stream(rng, 56);
            let opts = TrainOptions::default().with_c(0.5 + rng.uniform() * 4.0);
            laws::reduction_anchors(&st, opts, case % 2 == 0)
        },
    );
}

/// Sparse and dense physical representations of the same logical stream
/// must produce tolerance-identical state in every variant. The shared
/// law covers all five variants through [`AnyLearner`]; the inline tail
/// covers what needs concrete types — an RBF kernel and the anisotropic
/// ellipsoid metric axes.
#[test]
fn sparse_and_dense_trajectories_agree_across_variants() {
    check(
        "conformance-sparse-dense",
        PropConfig { cases: 16, seed: 0x5A55 },
        |rng, _| {
            let st = laws::gen_stream(rng, 48);
            let opts = TrainOptions::default();
            laws::sparse_dense_agree(&st, opts)?;

            let mut kd = KernelStreamSvm::new(Kernel::Rbf { gamma: 0.3 }, opts);
            let mut ks = KernelStreamSvm::new(Kernel::Rbf { gamma: 0.3 }, opts);
            let mut ed = EllipsoidSvm::new(st.dim, opts);
            let mut es = EllipsoidSvm::new(st.dim, opts);
            for i in 0..st.len() {
                let (x, v, y) = (&st.dense[i], st.sparse[i].view(), st.ys[i]);
                if kd.observe(x, y) != ks.observe_view(v, y) {
                    return Err(format!("RBF kernelized decisions diverged at {i}"));
                }
                if ed.observe(x, y) != es.observe_view(v, y) {
                    return Err(format!("ellipsoid decisions diverged at {i}"));
                }
            }
            for (name, rd, rs) in
                [("rbf", kd.radius(), ks.radius()), ("ellipsoid", ed.radius(), es.radius())]
            {
                if (rd - rs).abs() > 1e-6 * rd.max(1.0) {
                    return Err(format!("{name}: R diverged {rd} vs {rs}"));
                }
            }
            if kd.num_support() != ks.num_support() || ed.num_support() != es.num_support() {
                return Err("support counts diverged between representations".into());
            }
            for (a, b) in ed.axes().iter().zip(es.axes()) {
                if (a - b).abs() > 1e-9 * a.max(1.0) {
                    return Err(format!("ellipsoid metric diverged {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

/// The same laws through the unified [`AnyLearner`] surface: enum
/// dispatch must be a zero-cost veneer. Radius monotonicity holds when
/// driven generically (the shared law), and the final state — radius,
/// probe scores, support count — is *bit-identical* to the concrete
/// variant driven directly with the identical stream.
#[test]
fn any_learner_is_bit_identical_to_direct_variants() {
    check(
        "conformance-any-learner",
        PropConfig { cases: 10, seed: 0xA17E },
        |rng, _| {
            let st = laws::gen_stream(rng, 40);
            // lookahead > 1 so AnyLearner::new keeps it verbatim and the
            // concrete twin sees the exact same options
            let opts = TrainOptions::default()
                .with_c(0.5 + rng.uniform() * 4.0)
                .with_lookahead(2 + rng.below(5));
            let n = st.len();
            let probes: Vec<&[f32]> = st.dense.iter().take(8).map(|v| v.as_slice()).collect();
            for variant in Variant::ALL {
                // concrete twin, constructed exactly as AnyLearner::new does
                let (r_direct, m_direct, s_direct): (f64, usize, Vec<u64>) = match variant {
                    Variant::Ball => {
                        let mut m = StreamSvm::new(st.dim, opts);
                        for i in 0..n {
                            m.observe_view(st.sparse[i].view(), st.ys[i]);
                        }
                        StreamLearner::finish(&mut m);
                        (
                            m.radius(),
                            m.num_support(),
                            probes.iter().map(|p| Classifier::score(&m, p).to_bits()).collect(),
                        )
                    }
                    Variant::Lookahead => {
                        let mut m = LookaheadSvm::new(st.dim, opts);
                        for i in 0..n {
                            m.observe_view(st.sparse[i].view(), st.ys[i]);
                        }
                        m.finish();
                        (
                            m.radius(),
                            m.num_support(),
                            probes.iter().map(|p| Classifier::score(&m, p).to_bits()).collect(),
                        )
                    }
                    Variant::Kernelized => {
                        let mut m = KernelStreamSvm::new(Kernel::Linear, opts);
                        for i in 0..n {
                            m.observe_view(st.sparse[i].view(), st.ys[i]);
                        }
                        StreamLearner::finish(&mut m);
                        (
                            m.radius(),
                            m.num_support(),
                            probes.iter().map(|p| Classifier::score(&m, p).to_bits()).collect(),
                        )
                    }
                    Variant::Ellipsoid => {
                        let mut m = EllipsoidSvm::new(st.dim, opts);
                        for i in 0..n {
                            m.observe_view(st.sparse[i].view(), st.ys[i]);
                        }
                        StreamLearner::finish(&mut m);
                        (
                            m.radius(),
                            m.num_support(),
                            probes.iter().map(|p| Classifier::score(&m, p).to_bits()).collect(),
                        )
                    }
                    Variant::Multiball => {
                        let mut m = MultiBallSvm::new(
                            st.dim,
                            DEFAULT_MAX_BALLS,
                            MergePolicy::NearestBall,
                            opts,
                        );
                        for i in 0..n {
                            m.observe_view(st.sparse[i].view(), st.ys[i]);
                        }
                        StreamLearner::finish(&mut m);
                        (
                            StreamLearner::radius(&m),
                            m.num_support(),
                            probes.iter().map(|p| Classifier::score(&m, p).to_bits()).collect(),
                        )
                    }
                };
                // generic drive via the shared law (monotone + finish)
                let any = laws::any_learner_monotone(variant, &st, opts)?;
                if any.radius().to_bits() != r_direct.to_bits() {
                    return Err(format!(
                        "{variant}: AnyLearner R {} != direct {r_direct}",
                        any.radius()
                    ));
                }
                if any.num_support() != m_direct {
                    return Err(format!(
                        "{variant}: AnyLearner M {} != direct {m_direct}",
                        any.num_support()
                    ));
                }
                for (j, (p, want)) in probes.iter().zip(&s_direct).enumerate() {
                    if any.score(p).to_bits() != *want {
                        return Err(format!("{variant}: probe {j} score diverged"));
                    }
                }
                if any.examples_seen() != n {
                    return Err(format!(
                        "{variant}: examples_seen {} != {n}",
                        any.examples_seen()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Serialization is part of the conformance surface: every variant must
/// survive the v4 `.meb` codec with bit-identical radius and probe
/// scores (the shared [`laws::meb_round_trip`] law). The non-linear RBF
/// kernelized learner rides along: its sketch has no summary ball, only
/// the exact-state section.
#[test]
fn meb_round_trip_restores_every_variant_bit_identically() {
    check(
        "conformance-meb-round-trip",
        PropConfig { cases: 10, seed: 0x0DEC },
        |rng, _| {
            let st = laws::gen_stream(rng, 44);
            let opts = TrainOptions::default()
                .with_c(0.5 + rng.uniform() * 4.0)
                .with_lookahead(2 + rng.below(5));
            let mut learners: Vec<AnyLearner> =
                Variant::ALL.iter().map(|&v| AnyLearner::new(v, st.dim, opts)).collect();
            learners.push(AnyLearner::with_kernel(
                Variant::Kernelized,
                st.dim,
                opts,
                Kernel::Rbf { gamma: 0.25 },
            ));
            for m in &mut learners {
                for i in 0..st.len() {
                    m.observe_view(st.sparse[i].view(), st.ys[i]);
                }
                m.finish();
            }
            for m in &learners {
                laws::meb_round_trip(m, &st)?;
            }
            Ok(())
        },
    );
}

/// The validated entry points reject malformed input identically across
/// variants — same error classes, no state consumed (the PR-4
/// robustness contract). The unified-surface half is the shared
/// [`laws::try_observe_contract`]; the concrete-type half stays inline
/// because the kernelized learner pins its dimension lazily.
#[test]
fn try_observe_rejections_are_uniform_across_variants() {
    use streamsvm::data::FeaturesView;
    use streamsvm::error::Error;

    let opts = TrainOptions::default();
    for v in Variant::ALL {
        laws::try_observe_contract(v, opts).unwrap();
    }

    let good = [1.0f32, -2.0, 0.5];
    let nan = [1.0f32, f32::NAN, 0.5];
    let short = [1.0f32, 2.0];

    let mut a1 = StreamSvm::new(3, opts);
    let mut a2 = LookaheadSvm::new(3, opts.with_lookahead(4));
    let mut mb = MultiBallSvm::new(3, 2, MergePolicy::NearestBall, opts);
    let mut ker = KernelStreamSvm::new(Kernel::Linear, opts);
    let mut ell = EllipsoidSvm::new(3, opts);
    // pin the kernelized dimension with one valid example first
    assert!(ker.try_observe(FeaturesView::Dense(&good), 1.0).unwrap());

    macro_rules! expect {
        ($call:expr, $variant:path, $who:literal) => {{
            let err = $call.unwrap_err();
            assert!(matches!(err, $variant(_)), "{}: {err}", $who);
        }};
    }
    expect!(a1.try_observe(FeaturesView::Dense(&short), 1.0), Error::Config, "stream");
    expect!(a2.try_observe(FeaturesView::Dense(&short), 1.0), Error::Config, "lookahead");
    expect!(mb.try_observe(FeaturesView::Dense(&short), 1.0), Error::Config, "multiball");
    expect!(ker.try_observe(FeaturesView::Dense(&short), 1.0), Error::Config, "kernelized");
    expect!(ell.try_observe(FeaturesView::Dense(&short), 1.0), Error::Config, "ellipsoid");

    expect!(a1.try_observe(FeaturesView::Dense(&nan), 1.0), Error::Data, "stream");
    expect!(a2.try_observe(FeaturesView::Dense(&nan), 1.0), Error::Data, "lookahead");
    expect!(mb.try_observe(FeaturesView::Dense(&nan), 1.0), Error::Data, "multiball");
    expect!(ker.try_observe(FeaturesView::Dense(&nan), 1.0), Error::Data, "kernelized");
    expect!(ell.try_observe(FeaturesView::Dense(&nan), 1.0), Error::Data, "ellipsoid");

    expect!(a1.try_observe(FeaturesView::Dense(&good), 0.5), Error::Data, "stream");
    expect!(a2.try_observe(FeaturesView::Dense(&good), 0.5), Error::Data, "lookahead");
    expect!(mb.try_observe(FeaturesView::Dense(&good), 0.5), Error::Data, "multiball");
    expect!(ker.try_observe(FeaturesView::Dense(&good), 0.5), Error::Data, "kernelized");
    expect!(ell.try_observe(FeaturesView::Dense(&good), 0.5), Error::Data, "ellipsoid");

    // rejects consumed no stream position anywhere
    assert_eq!(a1.examples_seen(), 0);
    assert_eq!(a2.examples_seen(), 0);
    assert_eq!(mb.examples_seen(), 0);
    assert_eq!(ker.examples_seen(), 1);
    assert_eq!(ell.examples_seen(), 0);

    // and valid input still flows everywhere
    assert!(a1.try_observe(FeaturesView::Dense(&good), 1.0).is_ok());
    assert!(a2.try_observe(FeaturesView::Dense(&good), 1.0).is_ok());
    assert!(mb.try_observe(FeaturesView::Dense(&good), 1.0).is_ok());
    assert!(ker.try_observe(FeaturesView::Dense(&good), -1.0).is_ok());
    assert!(ell.try_observe(FeaturesView::Dense(&good), 1.0).is_ok());
}

/// The fuzz-tape decoder that feeds `fuzz --target invariants` is total
/// and deterministic: any byte string decodes to a runnable stream, and
/// the laws hold over tape-decoded cases exactly as over generated ones.
#[test]
fn invariant_laws_hold_over_fuzz_tapes() {
    let mut rng = Pcg32::seeded(0x7A9E);
    for case in 0..24 {
        let n = rng.below(300);
        let tape: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
        let (st, _, _) = laws::stream_case_from_tape(&tape);
        assert!(st.len() <= 96, "tape case {case} overflowed the row cap");
        laws::check_tape(&tape).unwrap_or_else(|e| panic!("tape case {case}: {e}"));
        // determinism: the same tape decodes to the same case
        let (st2, _, _) = laws::stream_case_from_tape(&tape);
        assert_eq!(st.dense, st2.dense);
        assert_eq!(st.ys, st2.ys);
    }
    // the empty tape is a valid (empty) case, laws vacuously hold
    let (st, _, _) = laws::stream_case_from_tape(&[]);
    assert!(st.is_empty());
    laws::check_tape(&[]).unwrap();
}

/// End-to-end sanity on a learnable stream: every variant separates the
/// same two-Gaussian data well (the conformance suite is about shared
/// laws, not about one variant quietly degenerating).
#[test]
fn every_variant_learns_the_same_separable_stream() {
    let mut rng = Pcg32::seeded(0x1EA2);
    let (xs, ys) = gen::labeled_points(&mut rng, 1500, 8, 1.0, 1.2);
    let exs: Vec<Example> =
        xs.into_iter().zip(ys).map(|(x, y)| Example::new(x, y)).collect();
    let opts = TrainOptions::default();
    let a1 = StreamSvm::fit(exs.iter(), 8, &opts);
    let a2 = LookaheadSvm::fit(exs.iter(), 8, &opts.with_lookahead(8));
    let mb = MultiBallSvm::fit(exs.iter(), 8, 3, MergePolicy::NearestBall, &opts);
    let ker = KernelStreamSvm::fit(exs.iter(), Kernel::Linear, &opts);
    let ell = EllipsoidSvm::fit(exs.iter(), 8, &opts);
    for (name, acc) in [
        ("stream", streamsvm::eval::accuracy(&a1, &exs)),
        ("lookahead", streamsvm::eval::accuracy(&a2, &exs)),
        ("multiball", streamsvm::eval::accuracy(&mb, &exs)),
        ("kernelized", streamsvm::eval::accuracy(&ker, &exs)),
        ("ellipsoid", streamsvm::eval::accuracy(&ell, &exs)),
    ] {
        assert!(acc > 0.85, "{name} acc = {acc:.3}");
    }
}
