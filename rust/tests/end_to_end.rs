//! End-to-end integration over pure-Rust paths (always runnable, no
//! artifacts needed): dataset generators → streaming pipeline →
//! evaluation, plus the Table-1 regime sanity checks at smoke scale.

use streamsvm::baselines::batch_l2svm::{BatchL2Svm, BatchL2SvmOptions};
use streamsvm::baselines::pegasos::{Pegasos, PegasosOptions};
use streamsvm::coordinator::pipeline::{train_stream, ExecMode, PipelineConfig};
use streamsvm::coordinator::stream::VecStream;
use streamsvm::data::registry::{load_dataset_sized, TABLE1_NAMES};
use streamsvm::eval::accuracy;
use streamsvm::svm::lookahead::LookaheadSvm;
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

#[test]
fn every_dataset_trains_and_beats_chance() {
    for name in TABLE1_NAMES {
        let ds = load_dataset_sized(name, 42, 0.05).unwrap();
        let c = streamsvm::exp::table1::c_for(name);
        let model = StreamSvm::fit(ds.train.iter(), ds.dim, &TrainOptions::default().with_c(c));
        let acc = accuracy(&model, &ds.test);
        // majority-class rate on the test split
        let pos = ds.test.iter().filter(|e| e.y > 0.0).count() as f64 / ds.test.len() as f64;
        let majority = pos.max(1.0 - pos);
        // one pass at 5% scale: demand above-chance behaviour everywhere,
        // and near-majority on the skewed sets
        assert!(
            acc > 0.5 * majority + 0.2,
            "{name}: acc {acc:.3} vs majority {majority:.3}"
        );
    }
}

#[test]
fn easy_datasets_reach_regime_accuracy() {
    // synthA and mnist01 are the paper's near-separable rows (≥95%).
    for (name, floor) in [("synthA", 0.90), ("mnist01", 0.95)] {
        let ds = load_dataset_sized(name, 42, 0.2).unwrap();
        let c = streamsvm::exp::table1::c_for(name);
        let algo2 = LookaheadSvm::fit(
            ds.train.iter(),
            ds.dim,
            &TrainOptions::default().with_c(c).with_lookahead(10),
        );
        let acc = accuracy(&algo2, &ds.test);
        assert!(acc > floor, "{name}: algo2 acc {acc:.3} < {floor}");
    }
}

#[test]
fn lookahead_beats_or_matches_algo1_on_hard_data() {
    // The Table-1 shape: Algo-2 ≥ Algo-1 (averaged over orders).
    let ds = load_dataset_sized("mnist89", 42, 0.15).unwrap();
    let c = streamsvm::exp::table1::c_for("mnist89");
    let mut a1_sum = 0.0;
    let mut a2_sum = 0.0;
    let runs = 5;
    for seed in 0..runs {
        let stream: Vec<_> = VecStream::of_train(&ds, Some(seed)).collect();
        let a1 = StreamSvm::fit(stream.iter(), ds.dim, &TrainOptions::default().with_c(c));
        let a2 = LookaheadSvm::fit(
            stream.iter(),
            ds.dim,
            &TrainOptions::default().with_c(c).with_lookahead(10),
        );
        a1_sum += accuracy(&a1, &ds.test);
        a2_sum += accuracy(&a2, &ds.test);
    }
    let (a1, a2) = (a1_sum / runs as f64, a2_sum / runs as f64);
    assert!(a2 + 0.02 >= a1, "algo2 {a2:.3} should be >= algo1 {a1:.3}");
}

#[test]
fn single_pass_streamsvm_competitive_with_single_sweep_pegasos() {
    // Table-1 shape: StreamSVM(Algo-2) is competitive with a single sweep
    // of Pegasos everywhere. (On the paper's real datasets Algo-2 wins
    // outright; our simulated generators are better-conditioned for SGD,
    // so the check is "within a few points", documented in EXPERIMENTS.md.)
    let mut ok = 0;
    let mut total = 0;
    for name in ["synthA", "synthC", "waveform", "mnist89"] {
        let ds = load_dataset_sized(name, 42, 0.1).unwrap();
        let c = streamsvm::exp::table1::c_for(name);
        let stream: Vec<_> = VecStream::of_train(&ds, Some(3)).collect();
        let a2 = LookaheadSvm::fit(
            stream.iter(),
            ds.dim,
            &TrainOptions::default().with_c(c).with_lookahead(10),
        );
        let lambda = Some(1.0 / (c * stream.len() as f64));
        let peg = Pegasos::fit(&stream, ds.dim, &PegasosOptions { k: 1, lambda });
        total += 1;
        if accuracy(&a2, &ds.test) >= accuracy(&peg, &ds.test) - 0.06 {
            ok += 1;
        }
    }
    assert!(ok >= total - 1, "StreamSVM competitive on only {ok}/{total} vs Pegasos k=1");
}

#[test]
fn batch_solver_is_the_upper_reference() {
    let ds = load_dataset_sized("waveform", 42, 0.5).unwrap();
    let batch = BatchL2Svm::fit(
        &ds.train,
        ds.dim,
        &BatchL2SvmOptions { max_epochs: 80, ..Default::default() },
    );
    let algo1 = StreamSvm::fit(ds.train.iter(), ds.dim, &TrainOptions::default());
    let (ab, a1) = (accuracy(&batch, &ds.test), accuracy(&algo1, &ds.test));
    assert!(ab + 0.02 >= a1, "batch {ab:.3} should be >= algo1 {a1:.3}");
    assert!(ab > 0.8, "batch acc {ab:.3} out of regime");
}

#[test]
fn pipeline_pure_mode_end_to_end_with_permutation() {
    let ds = load_dataset_sized("ijcnn", 42, 0.05).unwrap();
    let cfg = PipelineConfig {
        train: TrainOptions::default(),
        mode: ExecMode::Pure,
        block: Some(128),
        queue: 2,
        ..Default::default()
    };
    let stream = VecStream::of_train(&ds, Some(11));
    let report = train_stream(None, stream, ds.dim, cfg).unwrap();
    assert_eq!(report.metrics.examples, ds.train.len());
    let acc = accuracy(&report.model, &ds.test);
    assert!(acc > 0.5, "pipeline model acc {acc:.3}");
}
