//! Acceptance-criteria integration suite for the sketch subsystem:
//! interrupt/resume exactness on real dataset streams, sharded training
//! through the merge-and-reduce tree, and durability of sketch files
//! across the public API surface.

use streamsvm::coordinator::pipeline::{train_stream_ckpt, ExecMode, PipelineConfig};
use streamsvm::coordinator::sharded::{merge_shard_sketches, train_sharded};
use streamsvm::coordinator::stream::VecStream;
use streamsvm::data::registry::load_dataset_sized;
use streamsvm::data::Example;
use streamsvm::eval::accuracy;
use streamsvm::prop::{check, PropConfig};
use streamsvm::sketch::checkpoint::{resume_fit, resume_lookahead, CheckpointConfig, Checkpointer};
use streamsvm::sketch::codec::MebSketch;
use streamsvm::sketch::merge::merge_sketches;
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ssvm_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The headline guarantee: interrupt a one-pass run at an arbitrary
/// example index, round-trip the state through a sketch *file*, resume,
/// and the final weights are bit-identical to the uninterrupted run.
#[test]
fn interrupt_at_arbitrary_index_resume_bit_identical_on_real_data() {
    let dir = tmpdir("resume");
    let ds = load_dataset_sized("waveform", 42, 0.2).unwrap();
    let opts = TrainOptions::default();
    let full = StreamSvm::fit(ds.train.iter(), ds.dim, &opts);

    check("it-resume-exact", PropConfig { cases: 12, seed: 0x5E }, |rng, case| {
        let k = rng.below(ds.train.len() + 1);
        let mut partial = StreamSvm::new(ds.dim, opts);
        for e in ds.train.iter().take(k) {
            partial.observe_view(e.x.view(), e.y);
        }
        let path = dir.join(format!("cut{case}.meb"));
        MebSketch::from_model(&partial, "waveform")
            .write_to(&path)
            .map_err(|e| e.to_string())?;
        let sk = MebSketch::read_from(&path).map_err(|e| e.to_string())?;
        if sk.seen != k {
            return Err(format!("sketch seen {} != cut point {k}", sk.seen));
        }
        let resumed = resume_fit(&sk, ds.train.iter().cloned());
        if resumed.weights() != full.weights()
            || resumed.radius().to_bits() != full.radius().to_bits()
            || resumed.num_support() != full.num_support()
            || resumed.examples_seen() != full.examples_seen()
        {
            return Err(format!("resume at k={k} diverged from the uninterrupted run"));
        }
        Ok(())
    });
    std::fs::remove_dir_all(&dir).ok();
}

/// Same guarantee driven through the pipeline's checkpoint interval
/// machinery: crash after the last periodic snapshot, resume from disk.
#[test]
fn pipeline_checkpoint_interval_resume_bit_identical() {
    let dir = tmpdir("pipe");
    let ds = load_dataset_sized("synthC", 42, 0.05).unwrap();
    let path = dir.join("pipe.meb");
    let cfg = PipelineConfig { mode: ExecMode::Pure, block: Some(64), ..Default::default() };
    let mut ck = Checkpointer::new(CheckpointConfig {
        every: 250,
        path: path.clone(),
        tag: "synthC".into(),
    });
    let stream = VecStream::of_train(&ds, None);
    let report = train_stream_ckpt(None, stream, ds.dim, cfg, Some(&mut ck)).unwrap();
    assert!(ck.saves() >= 2, "expected multiple periodic checkpoints, got {}", ck.saves());

    let sk = MebSketch::read_from(&path).unwrap();
    assert!(sk.seen > 0 && sk.seen < ds.train.len());
    let resumed = resume_fit(&sk, VecStream::of_train(&ds, None));
    assert_eq!(Some(resumed.weights()), report.model.weights());
    assert_eq!(resumed.radius().to_bits(), report.model.radius().to_bits());
    assert_eq!(resumed.examples_seen(), ds.train.len());
    std::fs::remove_dir_all(&dir).ok();
}

/// Sharded training through the merge-and-reduce tree stays within the
/// documented 0.08 accuracy tolerance of the single-shard run — on a
/// real dataset, at several shard widths.
#[test]
fn sharded_tree_accuracy_within_tolerance_on_real_data() {
    let ds = load_dataset_sized("waveform", 42, 0.5).unwrap();
    let opts = TrainOptions::default();
    let single =
        train_sharded(ds.train.clone().into_iter(), ds.dim, 1, opts, 32).unwrap();
    let a1 = accuracy(&single.model, &ds.test);
    for shards in [2usize, 8, 16] {
        let rep =
            train_sharded(ds.train.clone().into_iter(), ds.dim, shards, opts, 32).unwrap();
        let a = accuracy(&rep.model, &ds.test);
        assert!(a > a1 - 0.08, "{shards} shards: {a:.3} vs single {a1:.3}");
        assert_eq!(rep.examples, ds.train.len());
        let max_r = rep.shard_radii.iter().cloned().fold(0.0f64, f64::max);
        assert!(rep.model.radius() + 1e-9 >= max_r);
    }
}

/// Distributed hand-off via files: each shard snapshots to disk, the
/// merger reads the files back and reduces — matching the live path.
#[test]
fn shard_sketch_files_merge_end_to_end() {
    let dir = tmpdir("files");
    let ds = load_dataset_sized("synthA", 42, 0.1).unwrap();
    let opts = TrainOptions::default();
    let shards = 6usize;
    let mut paths = Vec::new();
    for s in 0..shards {
        let mut m = StreamSvm::new(ds.dim, opts);
        for e in ds.train.iter().skip(s).step_by(shards) {
            m.observe_view(e.x.view(), e.y);
        }
        let p = dir.join(format!("s{s}.meb"));
        MebSketch::from_model(&m, format!("s{s}")).write_to(&p).unwrap();
        paths.push(p);
    }
    let sketches: Vec<MebSketch> =
        paths.iter().map(|p| MebSketch::read_from(p).unwrap()).collect();
    let rep = merge_shard_sketches(&sketches).unwrap();
    assert_eq!(rep.examples, ds.train.len());
    assert_eq!(rep.shard_radii.len(), shards);
    let single = StreamSvm::fit(ds.train.iter(), ds.dim, &opts);
    let (am, a1) = (accuracy(&rep.model, &ds.test), accuracy(&single, &ds.test));
    assert!(am > a1 - 0.08, "file-merged {am:.3} vs single {a1:.3}");

    // the merged sketch itself round-trips
    let merged = merge_sketches(&sketches).unwrap();
    let out = dir.join("merged.meb");
    merged.write_to(&out).unwrap();
    let back = MebSketch::read_from(&out).unwrap();
    assert_eq!(back, merged);
    std::fs::remove_dir_all(&dir).ok();
}

/// The paper's O(N/L) merge count must survive an interruption: a
/// checkpoint taken mid-stream records the merges so far, and the
/// resumed learner's final `num_merges()` equals the uninterrupted
/// run's. (Regression: `LookaheadSvm::from_ball` used to zero the
/// counter, so a resumed run under-reported merges by however many
/// happened before the checkpoint.)
#[test]
fn lookahead_resume_preserves_merge_count() {
    use streamsvm::svm::lookahead::LookaheadSvm;
    let dir = tmpdir("merges");
    // Adversarial 1-D stream (geometric growth, the same family the
    // lookahead unit tests use): points escape the ball essentially
    // always, so the buffer flushes regularly and a mid-stream cut has
    // merges on both sides of it.
    let n = 40usize;
    let exs: Vec<Example> =
        (0..n).map(|i| Example::new(vec![2.0f32.powi(i as i32)], 1.0)).collect();
    let l = 4usize;
    let opts = TrainOptions::default().with_lookahead(l);

    let full = LookaheadSvm::fit(exs.iter(), 1, &opts);
    assert!(full.num_merges() >= 2, "stream too tame: {} merges", full.num_merges());

    // walk to a buffer-empty cut past the midpoint (the checkpointer's
    // save precondition) and checkpoint there, recording the merge
    // count in provenance
    let mut m = LookaheadSvm::new(1, opts);
    let mut sk = None;
    for (i, e) in exs.iter().enumerate() {
        m.observe_view(e.x.view(), e.y);
        if sk.is_none()
            && i + 1 >= n / 2
            && i + 1 < n
            && m.buffered() == 0
            && m.num_merges() > 0
        {
            sk = Some(
                MebSketch::new(1, m.ball().cloned(), i + 1, opts, "merge-count")
                    .with_merges(m.num_merges()),
            );
        }
    }
    let sk = sk.expect("the adversarial stream has a buffer-empty cut past the midpoint");
    assert!(sk.merges > 0, "checkpoint must land after at least one merge");

    // round-trip through a real file, as an interruption would
    let path = dir.join("merges.meb");
    sk.write_to(&path).unwrap();
    let sk = MebSketch::read_from(&path).unwrap();
    assert!(sk.merges > 0);

    let resumed = resume_lookahead(&sk, exs.clone());
    assert_eq!(
        resumed.num_merges(),
        full.num_merges(),
        "resumed run misreports the O(N/L) merge count"
    );
    assert_eq!(resumed.weights(), full.weights());
    assert_eq!(resumed.radius().to_bits(), full.radius().to_bits());
    assert_eq!(resumed.examples_seen(), n);
    std::fs::remove_dir_all(&dir).ok();
}

/// Heterogeneous-options shards must be rejected, not silently merged.
#[test]
fn incompatible_shard_sketches_rejected() {
    let mk = |c: f64| {
        let e = Example::new(vec![1.0, 2.0], 1.0);
        let m = StreamSvm::fit([&e].into_iter().map(|x| &*x), 2, &TrainOptions::default().with_c(c));
        MebSketch::from_model(&m, "x")
    };
    let err = merge_sketches(&[mk(1.0), mk(4.0)]).unwrap_err();
    assert!(err.to_string().contains("incompatible"), "{err}");
}

/// Structure-aware corruption fuzz over every codec version, now driven
/// through the fuzz subsystem ([`streamsvm::fuzz`], the `codec` target):
/// truncated, bit-flipped, spliced and length-mutated `.meb` frames —
/// with checksums recomputed on half the cases so mutations reach the
/// structural validation layer — must come back as [`Err`] (or a
/// still-valid, re-encodable [`Ok`]), never a panic. This is the PR-9
/// `corrupted_sketch_bytes_error_never_panic` suite, migrated to the
/// harness as its first codec target.
#[test]
fn codec_fuzz_target_runs_clean() {
    use streamsvm::fuzz::{gen, run, FuzzConfig, Target};

    let dir = tmpdir("fuzz");
    let cfg = FuzzConfig {
        cases: 600,
        seed: 0xC0_22,
        persist_dir: Some(dir.join("failures")),
    };
    let report = run(Target::Codec, &cfg).unwrap();
    assert_eq!(report.executed, 600);
    assert!(
        report.clean(),
        "codec fuzz found failures: {:?} (first: {:?})",
        report.persisted,
        report.sample_failure
    );
    // lazy-dir contract: a clean run leaves no failures directory behind
    assert!(!dir.join("failures").exists());

    // the exhaustive sweeps the harness samples randomly stay pinned
    // here: every truncation of every base (all five v4 variants plus
    // the three legacy layouts) is an error, never a panic
    for (bi, good) in gen::meb_bases().iter().enumerate() {
        assert!(MebSketch::decode(good).is_ok(), "base {bi} must round-trip");
        for k in 0..good.len() {
            assert!(MebSketch::decode(&good[..k]).is_err(), "base {bi} cut at {k}");
        }
        // length-field mutations: the header's promised size must always
        // disagree with the actual buffer (overflow-checked, not added)
        for promised in [0u64, 1, good.len() as u64, u64::MAX, u64::MAX - 7, 1 << 60] {
            let mut bad = good.clone();
            bad[8..16].copy_from_slice(&promised.to_le_bytes());
            assert!(MebSketch::decode(&bad).is_err(), "base {bi} promised {promised}");
        }
    }

    // not-even-a-sketch inputs
    for junk in [&b""[..], b"MEBS", b"not a sketch at all", &[0u8; 64]] {
        assert!(MebSketch::decode(junk).is_err());
    }

    // the same guarantee through the file path `resume --from`/`merge
    // --inputs` use: a torn write decodes as an error, never a panic
    let base0 = &gen::meb_bases()[0];
    let torn = dir.join("torn.meb");
    std::fs::write(&torn, &base0[..base0.len() / 2]).unwrap();
    assert!(MebSketch::read_from(&torn).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
