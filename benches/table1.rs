//! Bench harness regenerating **Table 1** of the paper: single-pass
//! classification accuracies of every algorithm on all eight datasets,
//! averaged over random stream orders — plus per-algorithm single-pass
//! wall time on the largest dataset.
//!
//! Scale: default is a reduced-but-faithful run (25% of each training
//! split, 5 stream orders). Set `STREAMSVM_BENCH_FULL=1` for the paper's
//! full sizes (20 orders, 100% splits).

use streamsvm::bench_util::{time_once, Table};
use streamsvm::exp::table1;
use streamsvm::exp::ExpScale;

fn main() {
    let full = std::env::var("STREAMSVM_BENCH_FULL").is_ok();
    let scale = if full {
        ExpScale::default()
    } else {
        ExpScale { train_frac: 0.25, runs: 5, seed: 42 }
    };
    println!(
        "== Table 1: single-pass accuracies (frac={}, runs={}) ==",
        scale.train_frac, scale.runs
    );
    let (rows, wall) = time_once(|| table1::run(&scale).expect("table1"));
    table1::print(&rows);
    println!("\n(total wall time {wall:?})");

    // paper-shape assertions, reported not enforced
    println!("\nshape checks vs the paper:");
    for r in &rows {
        let batch = r.acc[0].0;
        let peg1 = r.acc[2].0;
        let algo1 = r.acc[5].0;
        let algo2 = r.acc[6].0;
        let ok1 = algo2 + 0.02 >= algo1;
        let ok2 = algo2 + 0.08 >= peg1;
        let ok3 = batch + 0.03 >= algo2 || algo2 > 0.9;
        println!(
            "  {:<9} algo2>=algo1 {}  algo2>~pegasos1 {}  batch>=~algo2 {}",
            r.dataset,
            if ok1 { "✓" } else { "✗" },
            if ok2 { "✓" } else { "✗" },
            if ok3 { "✓" } else { "✗" },
        );
    }

    // std-dev table (the paper reports averages over 20 runs)
    println!("\naccuracy std over stream orders (streaming algorithms):");
    let mut t = Table::new(&["Data Set", "Perceptron", "Pegasos k=1", "LASVM", "Algo-1", "Algo-2"]);
    for r in &rows {
        t.row(&[
            r.dataset.clone(),
            format!("{:.2}", r.acc[1].1 * 100.0),
            format!("{:.2}", r.acc[2].1 * 100.0),
            format!("{:.2}", r.acc[4].1 * 100.0),
            format!("{:.2}", r.acc[5].1 * 100.0),
            format!("{:.2}", r.acc[6].1 * 100.0),
        ]);
    }
    t.print();
}
