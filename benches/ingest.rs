//! Parallel-ingest throughput (`BENCH_ingest.json`): the two claims the
//! chunked-ingest refactor makes, measured end to end on one synthetic
//! LIBSVM file.
//!
//! 1. **Parse**: the chunked byte-level reader ([`FileStream`]'s engine)
//!    vs the legacy per-line reader ([`LineStream`]) over the same
//!    bytes, in MB/s. Same tolerant grammar, same `Example` sequence
//!    (asserted here); the chunked path just never allocates a `String`
//!    per row.
//! 2. **Train**: `--workers 4` vs `--workers 1` through
//!    [`parallel::ingest_file`] — parse *and* Algorithm-1 updates fan
//!    out across cores, worker balls fold through the Algorithm-2 merge
//!    tree — in rows/s.
//!
//! The full run streams 10M rows (~0.7 GiB on disk, written once to the
//! temp dir and deleted on exit). `STREAMSVM_BENCH_SMOKE=1` shrinks it
//! to 200k rows for the CI smoke step; the speedup ratios are the gated
//! quantities and hold at both sizes. Note the workers ratio needs
//! actual cores — on a 1-core box it hovers near (or below) 1x, which
//! is why only CI greps it.

use std::io::Write as _;
use std::path::{Path, PathBuf};

use streamsvm::bench_util::bench;
use streamsvm::bench_util::Table;
use streamsvm::coordinator::parallel::{ingest_file, IngestConfig, IngestReport};
use streamsvm::coordinator::stream::{FileStream, LineStream};
use streamsvm::rng::Pcg32;
use streamsvm::server::json::fmt_num;

const DIM: usize = 256;
const NNZ: usize = 8;

/// Write `rows` deterministic LIBSVM rows (`±1` label, `NNZ` ascending
/// 1-based indices, short `%.3` values) and return the byte size. Same
/// grammar `gen-data` emits, so the bench parses exactly what the CLI
/// paths parse.
fn write_stream(path: &Path, rows: usize, seed: u64) -> u64 {
    let f = std::fs::File::create(path).expect("create bench stream");
    let mut w = std::io::BufWriter::with_capacity(1 << 20, f);
    let mut rng = Pcg32::seeded(seed);
    let mut line = String::with_capacity(128);
    for _ in 0..rows {
        line.clear();
        let y = rng.label(0.5);
        line.push_str(if y > 0.0 { "+1" } else { "-1" });
        let mut idx: Vec<u32> = (0..NNZ).map(|_| 1 + rng.below(DIM) as u32).collect();
        idx.sort_unstable();
        idx.dedup();
        for &i in &idx {
            let shift = if (i as usize) < DIM / 16 { 0.5 * y } else { 0.0 };
            let v = (rng.range(-1.0, 1.0) + shift) as f32;
            line.push_str(&format!(" {i}:{v:.3}"));
        }
        line.push('\n');
        w.write_all(line.as_bytes()).expect("write bench stream");
    }
    w.flush().expect("flush bench stream");
    std::fs::metadata(path).expect("stat bench stream").len()
}

/// Best-of-`reps` end-to-end ingest rate at a worker count (the report's
/// wall clock covers read + parse + train + merge).
fn ingest_best(path: &Path, workers: usize, reps: usize) -> IngestReport {
    let mut best: Option<IngestReport> = None;
    let mut best_rate = f64::NEG_INFINITY;
    for _ in 0..reps {
        let rep = ingest_file(path, DIM, IngestConfig { workers, ..Default::default() })
            .expect("ingest run");
        if rep.rows_per_s() > best_rate {
            best_rate = rep.rows_per_s();
            best = Some(rep);
        }
    }
    best.expect("at least one rep")
}

fn main() {
    let smoke = std::env::var("STREAMSVM_BENCH_SMOKE").is_ok();
    let (rows, reps) = if smoke { (200_000, 3) } else { (10_000_000, 3) };
    let path = PathBuf::from(std::env::temp_dir())
        .join(format!("streamsvm_ingest_bench_{}.libsvm", std::process::id()));
    println!("== parallel ingest (rows={rows}, dim={DIM}, nnz={NNZ}, smoke={smoke}) ==");
    let bytes = write_stream(&path, rows, 42);
    let mb = bytes as f64 / (1024.0 * 1024.0);
    println!("stream: {} ({mb:.1} MiB)", path.display());

    // ---- parse: per-line vs chunked over the same bytes. One warmup
    // pass also faults the file into the page cache so both readers
    // measure parsing, not cold disk.
    let line_stats = bench(1, reps, || {
        let n = LineStream::open(&path, DIM).expect("line open").count();
        std::hint::black_box(n);
    });
    let chunked_stats = bench(1, reps, || {
        let n = FileStream::open(&path, DIM).expect("chunked open").count();
        std::hint::black_box(n);
    });
    let n_line = LineStream::open(&path, DIM).expect("line open").count();
    let n_chunked = FileStream::open(&path, DIM).expect("chunked open").count();
    assert_eq!(n_line, n_chunked, "readers disagree on the row count");
    assert_eq!(n_chunked, rows, "generator/parser row mismatch");
    let parse_mb_s_lines = mb / line_stats.p50.as_secs_f64().max(1e-9);
    let parse_mb_s_chunked = mb / chunked_stats.p50.as_secs_f64().max(1e-9);
    let parse_speedup = parse_mb_s_chunked / parse_mb_s_lines.max(1e-9);

    // ---- train: 1 vs 4 workers through the parallel driver.
    let rep1 = ingest_best(&path, 1, reps);
    let rep4 = ingest_best(&path, 4, reps);
    assert_eq!(rep1.rows, rows, "workers=1 dropped rows");
    assert_eq!(rep4.rows, rows, "workers=4 dropped rows");
    assert_eq!(rep1.skipped, 0, "generator produced malformed rows");
    assert_eq!(rep4.skipped, 0, "generator produced malformed rows");
    let (r1, r4) = (rep1.model.radius(), rep4.model.radius());
    assert!(
        r1.is_finite() && r4.is_finite() && (r1 - r4).abs() / r1.max(1e-12) < 0.5,
        "worker counts diverged far beyond merge-tree tolerance: R1={r1} R4={r4}"
    );
    let workers1_rows_per_s = rep1.rows_per_s();
    let workers4_rows_per_s = rep4.rows_per_s();
    let workers_speedup = workers4_rows_per_s / workers1_rows_per_s.max(1e-9);

    let mut t = Table::new(&["path", "MB/s", "rows/s", "speedup"]);
    t.row(&[
        "parse lines".into(),
        format!("{parse_mb_s_lines:.1}"),
        format!("{:.0}", rows as f64 / line_stats.p50.as_secs_f64().max(1e-9)),
        "1.0".into(),
    ]);
    t.row(&[
        "parse chunked".into(),
        format!("{parse_mb_s_chunked:.1}"),
        format!("{:.0}", rows as f64 / chunked_stats.p50.as_secs_f64().max(1e-9)),
        format!("{parse_speedup:.1}"),
    ]);
    t.row(&[
        "ingest workers=1".into(),
        format!("{:.1}", rep1.mb_per_s()),
        format!("{workers1_rows_per_s:.0}"),
        "1.0".into(),
    ]);
    t.row(&[
        "ingest workers=4".into(),
        format!("{:.1}", rep4.mb_per_s()),
        format!("{workers4_rows_per_s:.0}"),
        format!("{workers_speedup:.1}"),
    ]);
    t.print();
    println!(
        "speedup: {parse_speedup:.1}x parse (chunked vs lines), \
         {workers_speedup:.1}x ingest (4 vs 1 workers)"
    );

    let json = format!(
        concat!(
            r#"{{"rows":{},"dim":{},"nnz":{},"bytes":{},"#,
            r#""parse_mb_s_lines":{},"parse_mb_s_chunked":{},"parse_speedup":{},"#,
            r#""workers1_rows_per_s":{},"workers4_rows_per_s":{},"workers_speedup":{}}}"#
        ),
        rows,
        DIM,
        NNZ,
        bytes,
        fmt_num(parse_mb_s_lines),
        fmt_num(parse_mb_s_chunked),
        fmt_num(parse_speedup),
        fmt_num(workers1_rows_per_s),
        fmt_num(workers4_rows_per_s),
        fmt_num(workers_speedup),
    );
    std::fs::write(Path::new("BENCH_ingest.json"), &json).expect("write BENCH_ingest.json");
    println!("wrote BENCH_ingest.json: {json}");
    let _ = std::fs::remove_file(&path);
}
