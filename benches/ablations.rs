//! Design-choice ablations called out in DESIGN.md §6c:
//! multiball merge policies, the §6.2 ellipsoid prototype vs the ball,
//! and sharded one-pass training.

use std::time::Instant;

use streamsvm::bench_util::Table;
use streamsvm::coordinator::sharded::train_sharded;
use streamsvm::data::registry::load_dataset_sized;
use streamsvm::eval::accuracy;
use streamsvm::svm::ellipsoid::EllipsoidSvm;
use streamsvm::svm::lookahead::LookaheadSvm;
use streamsvm::svm::multiball::{MergePolicy, MultiBallSvm};
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

fn multiball_policies() {
    println!("\n-- multiball (§4.3): merge policies vs lookahead --");
    let ds = load_dataset_sized("mnist89", 42, 0.2).expect("dataset");
    let c = streamsvm::exp::table1::c_for("mnist89");
    let opts = TrainOptions::default().with_c(c);
    let mut t = Table::new(&["variant", "L", "acc %", "state floats"]);
    for l in [1usize, 4, 8] {
        for (name, policy) in [
            ("nearest-ball", MergePolicy::NearestBall),
            ("new+collapse", MergePolicy::NewBallMergeClosest),
        ] {
            let m = MultiBallSvm::fit(ds.train.iter(), ds.dim, l, policy, &opts);
            t.row(&[
                name.into(),
                l.to_string(),
                format!("{:.2}", accuracy(&m, &ds.test) * 100.0),
                format!("{}", l * (ds.dim + 1)),
            ]);
        }
        let la = LookaheadSvm::fit(ds.train.iter(), ds.dim, &opts.with_lookahead(l));
        t.row(&[
            "lookahead".into(),
            l.to_string(),
            format!("{:.2}", accuracy(&la, &ds.test) * 100.0),
            format!("{}", l * (ds.dim + 1)),
        ]);
    }
    t.print();
}

fn ellipsoid_vs_ball() {
    println!("\n-- ellipsoid prototype (§6.2) vs ball on anisotropic data --");
    let mut t = Table::new(&["dataset", "ball acc %", "ellipsoid acc %"]);
    for name in ["synthC", "waveform", "ijcnn"] {
        let ds = load_dataset_sized(name, 42, 0.25).expect("dataset");
        let c = streamsvm::exp::table1::c_for(name);
        let opts = TrainOptions::default().with_c(c);
        let ball = StreamSvm::fit(ds.train.iter(), ds.dim, &opts);
        let ell = EllipsoidSvm::fit(ds.train.iter(), ds.dim, &opts);
        t.row(&[
            name.into(),
            format!("{:.2}", accuracy(&ball, &ds.test) * 100.0),
            format!("{:.2}", accuracy(&ell, &ds.test) * 100.0),
        ]);
    }
    t.print();
}

fn sharding() {
    println!("\n-- sharded one-pass training (distributed extension) --");
    let ds = load_dataset_sized("w3a", 42, 0.5).expect("dataset");
    let c = streamsvm::exp::table1::c_for("w3a");
    let opts = TrainOptions::default().with_c(c);
    let mut t = Table::new(&["shards", "acc %", "wall ms", "max shard R", "merged R"]);
    for s in [1usize, 2, 4, 8] {
        let t0 = Instant::now();
        let rep = train_sharded(ds.train.clone().into_iter(), ds.dim, s, opts, 64).unwrap();
        let wall = t0.elapsed();
        let max_r = rep.shard_radii.iter().cloned().fold(0.0f64, f64::max);
        t.row(&[
            s.to_string(),
            format!("{:.2}", accuracy(&rep.model, &ds.test) * 100.0),
            format!("{:.1}", wall.as_secs_f64() * 1e3),
            format!("{max_r:.3}"),
            format!("{:.3}", rep.model.radius()),
        ]);
    }
    t.print();
}

fn main() {
    println!("== design-choice ablations ==");
    multiball_policies();
    ellipsoid_vs_ball();
    sharding();
}
