//! Sparse vs dense hot paths at w3a-like density (`BENCH_sparse.json`):
//! the Algorithm-1 per-example update, the Algorithm-2 lookahead flush
//! (L > 1, where the merge Gram used to densify every survivor), the
//! kernelized variant (O(M·nnz) norm-expansion kernel evals vs O(M·D)
//! densified ones) and the diagonal-metric ellipsoid (O(nnz) scaled
//! reductions vs O(D)).
//!
//! Generates one synthetic stream at ~4% density and D ≥ 10k, runs the
//! identical stream through each learner twice — once with sparse
//! `idx`/`val` features, once densified — and reports per-example cost
//! plus the speedup ratios. The runs must agree on the learned state
//! (tolerance-checked here; the exact property tests live in
//! `rust/tests/sparse_path.rs` and `rust/tests/variant_conformance.rs`).
//!
//! `STREAMSVM_BENCH_SMOKE=1` shrinks the stream for the CI smoke step
//! (the dimension stays ≥ 10k so the measured regime is the real one).

use std::path::Path;

use streamsvm::bench_util::{bench, Table};
use streamsvm::data::Example;
use streamsvm::rng::Pcg32;
use streamsvm::server::json::fmt_num;
use streamsvm::svm::ellipsoid::EllipsoidSvm;
use streamsvm::svm::kernelfn::Kernel;
use streamsvm::svm::kernelized::KernelStreamSvm;
use streamsvm::svm::lookahead::LookaheadSvm;
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

const DIM: usize = 16_384;
const DENSITY: f64 = 0.04;
/// Lookahead width for the Algorithm-2 column.
const LOOKAHEAD: usize = 8;

/// A stream of sparse examples: `nnz` random coordinates each, values
/// N(0,1) plus a label-aligned shift on a shared prefix of coordinates
/// (so the stream is learnable and updates actually happen).
fn gen_sparse_stream(n: usize, dim: usize, nnz: usize, seed: u64) -> Vec<Example> {
    let mut rng = Pcg32::seeded(seed);
    let mut taken = vec![false; dim];
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let y = rng.label(0.5);
        let mut idx = Vec::with_capacity(nnz);
        while idx.len() < nnz {
            let i = rng.below(dim);
            if !taken[i] {
                taken[i] = true;
                idx.push(i as u32);
            }
        }
        for &i in &idx {
            taken[i as usize] = false;
        }
        idx.sort_unstable();
        let val: Vec<f32> = idx
            .iter()
            .map(|&i| {
                let shift = if (i as usize) < dim / 20 { 0.6 * y as f64 } else { 0.0 };
                (rng.normal() + shift) as f32
            })
            .collect();
        out.push(Example::sparse(dim, idx, val, y));
    }
    out
}

fn fit_ns_per_example(stream: &[Example], dim: usize, opts: &TrainOptions, reps: usize) -> (f64, StreamSvm) {
    let stats = bench(1, reps, || {
        let m = StreamSvm::fit(stream.iter(), dim, opts);
        std::hint::black_box(m.radius());
    });
    let model = StreamSvm::fit(stream.iter(), dim, opts);
    (stats.p50.as_nanos() as f64 / stream.len() as f64, model)
}

fn fit_lookahead_ns(
    stream: &[Example],
    dim: usize,
    opts: &TrainOptions,
    reps: usize,
) -> (f64, LookaheadSvm) {
    let stats = bench(1, reps, || {
        let m = LookaheadSvm::fit(stream.iter(), dim, opts);
        std::hint::black_box(m.radius());
    });
    let model = LookaheadSvm::fit(stream.iter(), dim, opts);
    (stats.p50.as_nanos() as f64 / stream.len() as f64, model)
}

fn fit_kernel_ns(
    stream: &[Example],
    kernel: Kernel,
    opts: &TrainOptions,
    reps: usize,
) -> (f64, KernelStreamSvm) {
    let stats = bench(1, reps, || {
        let m = KernelStreamSvm::fit(stream.iter(), kernel, opts);
        std::hint::black_box(m.radius());
    });
    let model = KernelStreamSvm::fit(stream.iter(), kernel, opts);
    (stats.p50.as_nanos() as f64 / stream.len() as f64, model)
}

fn fit_ellipsoid_ns(
    stream: &[Example],
    dim: usize,
    opts: &TrainOptions,
    reps: usize,
) -> (f64, EllipsoidSvm) {
    let stats = bench(1, reps, || {
        let m = EllipsoidSvm::fit(stream.iter(), dim, opts);
        std::hint::black_box(m.radius());
    });
    let model = EllipsoidSvm::fit(stream.iter(), dim, opts);
    (stats.p50.as_nanos() as f64 / stream.len() as f64, model)
}

fn main() {
    let smoke = std::env::var("STREAMSVM_BENCH_SMOKE").is_ok();
    let (n, reps) = if smoke { (600, 3) } else { (4000, 5) };
    let nnz = (DIM as f64 * DENSITY) as usize;
    println!(
        "== sparse vs dense update throughput (dim={DIM}, nnz={nnz}, n={n}, smoke={smoke}) =="
    );
    let sparse = gen_sparse_stream(n, DIM, nnz, 42);
    let dense: Vec<Example> = sparse
        .iter()
        .map(|e| Example::new(e.x.dense().into_owned(), e.y))
        .collect();
    let opts = TrainOptions::default();

    let (sparse_ns, ms) = fit_ns_per_example(&sparse, DIM, &opts, reps);
    let (dense_ns, md) = fit_ns_per_example(&dense, DIM, &opts, reps);
    let speedup = dense_ns / sparse_ns;
    let radius_rel_diff = (ms.radius() - md.radius()).abs() / md.radius().max(1e-12);
    assert_eq!(ms.num_support(), md.num_support(), "paths diverged on update count");
    assert!(radius_rel_diff < 1e-6, "paths diverged on radius: {radius_rel_diff}");

    // ---- Algorithm-2 lookahead column: the flush cost (merge Gram +
    // center reconstruction) is where sparse buffers pay off beyond the
    // per-example distance test.
    let la_opts = TrainOptions::default().with_lookahead(LOOKAHEAD);
    let (la_sparse_ns, las) = fit_lookahead_ns(&sparse, DIM, &la_opts, reps);
    let (la_dense_ns, lad) = fit_lookahead_ns(&dense, DIM, &la_opts, reps);
    let la_speedup = la_dense_ns / la_sparse_ns;
    assert_eq!(las.num_merges(), lad.num_merges(), "lookahead paths diverged on merges");
    assert_eq!(las.num_support(), lad.num_support(), "lookahead paths diverged on M");
    let la_radius_rel_diff =
        (las.radius() - lad.radius()).abs() / lad.radius().max(1e-12);
    assert!(la_radius_rel_diff < 1e-6, "lookahead paths diverged on radius: {la_radius_rel_diff}");

    // ---- kernelized column: each example pays O(M) kernel evals, so the
    // dense path is O(M·D) against O(M·nnz) (merge-join between stored
    // sparse core points). The core set grows with updates — a shorter
    // prefix keeps the densified reference affordable.
    let kern_n = if smoke { 150 } else { 500 };
    let kern_reps = reps.min(3);
    let kopts = TrainOptions::default();
    let (kern_sparse_ns, kms) =
        fit_kernel_ns(&sparse[..kern_n], Kernel::Linear, &kopts, kern_reps);
    let (kern_dense_ns, kmd) = fit_kernel_ns(&dense[..kern_n], Kernel::Linear, &kopts, kern_reps);
    let kern_speedup = kern_dense_ns / kern_sparse_ns;
    assert_eq!(
        kms.num_support(),
        kmd.num_support(),
        "kernelized paths diverged on the core-set size"
    );
    let kern_radius_rel_diff = (kms.radius() - kmd.radius()).abs() / kmd.radius().max(1e-12);
    assert!(
        kern_radius_rel_diff < 1e-6,
        "kernelized paths diverged on radius: {kern_radius_rel_diff}"
    );

    // ---- ellipsoid column: O(nnz) scaled reductions + per-touched-axis
    // metric adaptation vs the O(D) densified pass.
    let (ell_sparse_ns, ems) = fit_ellipsoid_ns(&sparse, DIM, &opts, reps);
    let (ell_dense_ns, emd) = fit_ellipsoid_ns(&dense, DIM, &opts, reps);
    let ell_speedup = ell_dense_ns / ell_sparse_ns;
    assert_eq!(ems.num_support(), emd.num_support(), "ellipsoid paths diverged on updates");
    let ell_radius_rel_diff = (ems.radius() - emd.radius()).abs() / emd.radius().max(1e-12);
    assert!(
        ell_radius_rel_diff < 1e-6,
        "ellipsoid paths diverged on radius: {ell_radius_rel_diff}"
    );

    let mut t = Table::new(&["path", "ns/example", "examples/s", "updates", "merges"]);
    for (name, ns, updates, merges) in [
        ("dense", dense_ns, md.num_support(), 0),
        ("sparse", sparse_ns, ms.num_support(), 0),
        (
            "dense L=8",
            la_dense_ns,
            lad.num_support(),
            lad.num_merges(),
        ),
        (
            "sparse L=8",
            la_sparse_ns,
            las.num_support(),
            las.num_merges(),
        ),
        ("dense kern", kern_dense_ns, kmd.num_support(), 0),
        ("sparse kern", kern_sparse_ns, kms.num_support(), 0),
        ("dense ell", ell_dense_ns, emd.num_support(), 0),
        ("sparse ell", ell_sparse_ns, ems.num_support(), 0),
    ] {
        t.row(&[
            name.into(),
            format!("{ns:.0}"),
            format!("{:.0}", 1e9 / ns),
            updates.to_string(),
            merges.to_string(),
        ]);
    }
    t.print();
    println!(
        "speedup: {speedup:.1}x (L=1), {la_speedup:.1}x (L={LOOKAHEAD}), \
         {kern_speedup:.1}x (kernelized, n={kern_n}), {ell_speedup:.1}x (ellipsoid) \
         at density {:.1}%",
        DENSITY * 100.0
    );

    let json = format!(
        concat!(
            r#"{{"dim":{},"n":{},"nnz":{},"density":{},"#,
            r#""dense_ns_per_example":{},"sparse_ns_per_example":{},"#,
            r#""dense_eps":{},"sparse_eps":{},"speedup":{},"#,
            r#""updates":{},"radius_rel_diff":{},"#,
            r#""lookahead":{},"la_dense_ns_per_example":{},"la_sparse_ns_per_example":{},"#,
            r#""la_speedup":{},"la_merges":{},"la_radius_rel_diff":{},"#,
            r#""kern_n":{},"kern_dense_ns_per_example":{},"kern_sparse_ns_per_example":{},"#,
            r#""kern_speedup":{},"kern_supports":{},"kern_radius_rel_diff":{},"#,
            r#""ell_dense_ns_per_example":{},"ell_sparse_ns_per_example":{},"#,
            r#""ell_speedup":{},"ell_updates":{},"ell_radius_rel_diff":{}}}"#
        ),
        DIM,
        n,
        nnz,
        fmt_num(DENSITY),
        fmt_num(dense_ns),
        fmt_num(sparse_ns),
        fmt_num(1e9 / dense_ns),
        fmt_num(1e9 / sparse_ns),
        fmt_num(speedup),
        ms.num_support(),
        fmt_num(radius_rel_diff),
        LOOKAHEAD,
        fmt_num(la_dense_ns),
        fmt_num(la_sparse_ns),
        fmt_num(la_speedup),
        las.num_merges(),
        fmt_num(la_radius_rel_diff),
        kern_n,
        fmt_num(kern_dense_ns),
        fmt_num(kern_sparse_ns),
        fmt_num(kern_speedup),
        kms.num_support(),
        fmt_num(kern_radius_rel_diff),
        fmt_num(ell_dense_ns),
        fmt_num(ell_sparse_ns),
        fmt_num(ell_speedup),
        ems.num_support(),
        fmt_num(ell_radius_rel_diff),
    );
    std::fs::write(Path::new("BENCH_sparse.json"), &json).expect("write BENCH_sparse.json");
    println!("wrote BENCH_sparse.json: {json}");
}
