//! Sparse vs dense hot paths at w3a-like density (`BENCH_sparse.json`):
//! the Algorithm-1 per-example update and the Algorithm-2 lookahead
//! flush (L > 1, where the merge Gram used to densify every survivor).
//!
//! Generates one synthetic stream at ~4% density and D ≥ 10k, runs the
//! identical stream through `StreamSvm::observe_view` (and
//! `LookaheadSvm` at L = 8) twice — once with sparse `idx`/`val`
//! features (O(nnz) per example, O(L²·nnz) per flush), once densified
//! (O(D) / O(L²·D)) — and reports per-example cost plus the speedup
//! ratios. The runs must agree on the learned state (tolerance-checked
//! here; the exact property tests live in `rust/tests/sparse_path.rs`).
//!
//! `STREAMSVM_BENCH_SMOKE=1` shrinks the stream for the CI smoke step
//! (the dimension stays ≥ 10k so the measured regime is the real one).

use std::path::Path;

use streamsvm::bench_util::{bench, Table};
use streamsvm::data::Example;
use streamsvm::rng::Pcg32;
use streamsvm::server::json::fmt_num;
use streamsvm::svm::lookahead::LookaheadSvm;
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

const DIM: usize = 16_384;
const DENSITY: f64 = 0.04;
/// Lookahead width for the Algorithm-2 column.
const LOOKAHEAD: usize = 8;

/// A stream of sparse examples: `nnz` random coordinates each, values
/// N(0,1) plus a label-aligned shift on a shared prefix of coordinates
/// (so the stream is learnable and updates actually happen).
fn gen_sparse_stream(n: usize, dim: usize, nnz: usize, seed: u64) -> Vec<Example> {
    let mut rng = Pcg32::seeded(seed);
    let mut taken = vec![false; dim];
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let y = rng.label(0.5);
        let mut idx = Vec::with_capacity(nnz);
        while idx.len() < nnz {
            let i = rng.below(dim);
            if !taken[i] {
                taken[i] = true;
                idx.push(i as u32);
            }
        }
        for &i in &idx {
            taken[i as usize] = false;
        }
        idx.sort_unstable();
        let val: Vec<f32> = idx
            .iter()
            .map(|&i| {
                let shift = if (i as usize) < dim / 20 { 0.6 * y as f64 } else { 0.0 };
                (rng.normal() + shift) as f32
            })
            .collect();
        out.push(Example::sparse(dim, idx, val, y));
    }
    out
}

fn fit_ns_per_example(stream: &[Example], dim: usize, opts: &TrainOptions, reps: usize) -> (f64, StreamSvm) {
    let stats = bench(1, reps, || {
        let m = StreamSvm::fit(stream.iter(), dim, opts);
        std::hint::black_box(m.radius());
    });
    let model = StreamSvm::fit(stream.iter(), dim, opts);
    (stats.p50.as_nanos() as f64 / stream.len() as f64, model)
}

fn fit_lookahead_ns(
    stream: &[Example],
    dim: usize,
    opts: &TrainOptions,
    reps: usize,
) -> (f64, LookaheadSvm) {
    let stats = bench(1, reps, || {
        let m = LookaheadSvm::fit(stream.iter(), dim, opts);
        std::hint::black_box(m.radius());
    });
    let model = LookaheadSvm::fit(stream.iter(), dim, opts);
    (stats.p50.as_nanos() as f64 / stream.len() as f64, model)
}

fn main() {
    let smoke = std::env::var("STREAMSVM_BENCH_SMOKE").is_ok();
    let (n, reps) = if smoke { (600, 3) } else { (4000, 5) };
    let nnz = (DIM as f64 * DENSITY) as usize;
    println!(
        "== sparse vs dense update throughput (dim={DIM}, nnz={nnz}, n={n}, smoke={smoke}) =="
    );
    let sparse = gen_sparse_stream(n, DIM, nnz, 42);
    let dense: Vec<Example> = sparse
        .iter()
        .map(|e| Example::new(e.x.dense().into_owned(), e.y))
        .collect();
    let opts = TrainOptions::default();

    let (sparse_ns, ms) = fit_ns_per_example(&sparse, DIM, &opts, reps);
    let (dense_ns, md) = fit_ns_per_example(&dense, DIM, &opts, reps);
    let speedup = dense_ns / sparse_ns;
    let radius_rel_diff = (ms.radius() - md.radius()).abs() / md.radius().max(1e-12);
    assert_eq!(ms.num_support(), md.num_support(), "paths diverged on update count");
    assert!(radius_rel_diff < 1e-6, "paths diverged on radius: {radius_rel_diff}");

    // ---- Algorithm-2 lookahead column: the flush cost (merge Gram +
    // center reconstruction) is where sparse buffers pay off beyond the
    // per-example distance test.
    let la_opts = TrainOptions::default().with_lookahead(LOOKAHEAD);
    let (la_sparse_ns, las) = fit_lookahead_ns(&sparse, DIM, &la_opts, reps);
    let (la_dense_ns, lad) = fit_lookahead_ns(&dense, DIM, &la_opts, reps);
    let la_speedup = la_dense_ns / la_sparse_ns;
    assert_eq!(las.num_merges(), lad.num_merges(), "lookahead paths diverged on merges");
    assert_eq!(las.num_support(), lad.num_support(), "lookahead paths diverged on M");
    let la_radius_rel_diff =
        (las.radius() - lad.radius()).abs() / lad.radius().max(1e-12);
    assert!(la_radius_rel_diff < 1e-6, "lookahead paths diverged on radius: {la_radius_rel_diff}");

    let mut t = Table::new(&["path", "ns/example", "examples/s", "updates", "merges"]);
    for (name, ns, updates, merges) in [
        ("dense", dense_ns, md.num_support(), 0),
        ("sparse", sparse_ns, ms.num_support(), 0),
        (
            "dense L=8",
            la_dense_ns,
            lad.num_support(),
            lad.num_merges(),
        ),
        (
            "sparse L=8",
            la_sparse_ns,
            las.num_support(),
            las.num_merges(),
        ),
    ] {
        t.row(&[
            name.into(),
            format!("{ns:.0}"),
            format!("{:.0}", 1e9 / ns),
            updates.to_string(),
            merges.to_string(),
        ]);
    }
    t.print();
    println!(
        "speedup: {speedup:.1}x (L=1), {la_speedup:.1}x (L={LOOKAHEAD}) at density {:.1}%",
        DENSITY * 100.0
    );

    let json = format!(
        concat!(
            r#"{{"dim":{},"n":{},"nnz":{},"density":{},"#,
            r#""dense_ns_per_example":{},"sparse_ns_per_example":{},"#,
            r#""dense_eps":{},"sparse_eps":{},"speedup":{},"#,
            r#""updates":{},"radius_rel_diff":{},"#,
            r#""lookahead":{},"la_dense_ns_per_example":{},"la_sparse_ns_per_example":{},"#,
            r#""la_speedup":{},"la_merges":{},"la_radius_rel_diff":{}}}"#
        ),
        DIM,
        n,
        nnz,
        fmt_num(DENSITY),
        fmt_num(dense_ns),
        fmt_num(sparse_ns),
        fmt_num(1e9 / dense_ns),
        fmt_num(1e9 / sparse_ns),
        fmt_num(speedup),
        ms.num_support(),
        fmt_num(radius_rel_diff),
        LOOKAHEAD,
        fmt_num(la_dense_ns),
        fmt_num(la_sparse_ns),
        fmt_num(la_speedup),
        las.num_merges(),
        fmt_num(la_radius_rel_diff),
    );
    std::fs::write(Path::new("BENCH_sparse.json"), &json).expect("write BENCH_sparse.json");
    println!("wrote BENCH_sparse.json: {json}");
}
