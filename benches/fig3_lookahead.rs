//! Bench harness regenerating **Figure 3**: lookahead sweep on MNIST
//! 8vs9 — mean ± std accuracy over random stream permutations per L.
//!
//! `STREAMSVM_BENCH_FULL=1` → 100 permutations on the full split.

use streamsvm::bench_util::time_once;
use streamsvm::exp::{fig3, ExpScale};

fn main() {
    let full = std::env::var("STREAMSVM_BENCH_FULL").is_ok();
    let (scale, perms, ls): (_, usize, &[usize]) = if full {
        (ExpScale::default(), 100, &fig3::DEFAULT_LS)
    } else {
        (
            ExpScale { train_frac: 0.15, runs: 1, seed: 42 },
            20,
            &[1, 2, 5, 10, 20, 50],
        )
    };
    println!(
        "== Figure 3: lookahead sweep (mnist89, frac={}, {perms} permutations/L) ==",
        scale.train_frac
    );
    let (pts, wall) = time_once(|| fig3::run("mnist89", ls, perms, &scale).expect("fig3"));
    fig3::print(&pts);
    println!("\n(wall time {wall:?})");

    let first = &pts[0];
    let best = pts.iter().map(|p| p.mean).fold(f64::MIN, f64::max);
    let l10 = pts.iter().find(|p| p.l == 10);
    println!("shape checks vs the paper:");
    println!(
        "  accuracy rises with L: {}",
        if best >= first.mean { "✓" } else { "✗" }
    );
    if let Some(p10) = l10 {
        println!(
            "  converged by L≈10 (within 1% of best): {}",
            if p10.mean + 0.01 >= best { "✓" } else { "✗" }
        );
    }
    let (s1, sl) = (first.std, pts.last().unwrap().std);
    println!(
        "  std shrinks with L ({:.2}% → {:.2}%): {}",
        s1 * 100.0,
        sl * 100.0,
        if sl <= s1 + 0.002 { "✓" } else { "✗" }
    );
}
