//! Bench harness regenerating **Figure 2**: passes the batch CVM needs
//! over MNIST 8vs9 before it reaches the single-pass StreamSVM accuracy.
//!
//! `STREAMSVM_BENCH_FULL=1` runs the full split with a 512-pass budget.

use streamsvm::bench_util::time_once;
use streamsvm::exp::{fig2, ExpScale};

fn main() {
    let full = std::env::var("STREAMSVM_BENCH_FULL").is_ok();
    let (scale, max_passes) = if full {
        (ExpScale::default(), 512)
    } else {
        (ExpScale { train_frac: 0.15, runs: 1, seed: 42 }, 160)
    };
    println!(
        "== Figure 2: CVM passes vs one StreamSVM pass (mnist89, frac={}) ==",
        scale.train_frac
    );
    let (f, wall) = time_once(|| fig2::run("mnist89", max_passes, &scale).expect("fig2"));
    fig2::print(&f);
    println!("\n(wall time {wall:?})");
    println!(
        "shape check: CVM needs many passes (paper: hundreds) — {}",
        match f.passes_to_beat {
            Some(p) if p > 10 => format!("✓ ({p} passes)"),
            Some(p) => format!("✗ (only {p} passes)"),
            None => format!("✓ (> {} passes)", f.cvm_curve.len()),
        }
    );
}
