//! Performance benches (EXPERIMENTS.md §Perf): pipeline throughput per
//! execution mode, block-size ablation, per-example update cost, and
//! serving latency.
//!
//! Not a paper table — this is the systems ablation for the three-layer
//! architecture: how much the block filter (one PJRT distance call per
//! block) buys over pure sequential Rust, and what the all-XLA scan
//! costs.

use streamsvm::bench_util::{bench, Table};
use streamsvm::coordinator::pipeline::{train_stream, ExecMode, PipelineConfig};
use streamsvm::coordinator::service::{PredictService, ServiceConfig};
use streamsvm::data::registry::load_dataset_sized;
use streamsvm::data::Example;
use streamsvm::runtime::Runtime;
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

fn pipeline_throughput(ds_name: &str, frac: f64) {
    let ds = load_dataset_sized(ds_name, 42, frac).expect("dataset");
    println!(
        "\n-- pipeline throughput: {} ({} examples, dim {}) --",
        ds.name,
        ds.train.len(),
        ds.dim
    );
    let mut t = Table::new(&[
        "mode", "kernels", "block", "examples/s", "filter %", "xla ms", "rust ms", "updates",
    ]);
    // (mode, prefer_fast, block override)
    let rows: &[(ExecMode, bool, Option<usize>)] = &[
        (ExecMode::Pure, true, None),
        (ExecMode::Filter, false, None),  // Pallas-interpret artifacts
        (ExecMode::Filter, true, None),   // native-jnp artifacts (kernel selection)
        (ExecMode::Filter, true, Some(1024)), // call-overhead amortization
        (ExecMode::Scan, true, None),
    ];
    for &(mode, fast, block) in rows {
        let mut rt = if mode == ExecMode::Pure { None } else { Runtime::open_default().ok() };
        if rt.is_none() && mode != ExecMode::Pure {
            println!("   ({mode:?}: no artifacts, skipped)");
            continue;
        }
        if let Some(rt) = rt.as_mut() {
            rt.set_prefer_fast(fast);
        }
        let cfg = PipelineConfig {
            train: TrainOptions::default().with_c(10.0),
            mode,
            block,
            queue: 4,
            ..Default::default()
        };
        let train = ds.train.clone();
        // one warm run (compile), one measured run
        let _ = train_stream(rt.as_mut(), train.clone().into_iter(), ds.dim, cfg);
        let report = train_stream(rt.as_mut(), train.into_iter(), ds.dim, cfg).expect("train");
        let m = &report.metrics;
        t.row(&[
            format!("{mode:?}"),
            if mode == ExecMode::Pure {
                "-".into()
            } else if fast {
                "jnp".into()
            } else {
                "pallas".into()
            },
            block.map(|b| b.to_string()).unwrap_or_else(|| "256".into()),
            format!("{:.0}", m.throughput()),
            format!("{:.1}", m.filter_rate() * 100.0),
            format!("{:.1}", m.xla_ns as f64 * 1e-6),
            format!("{:.1}", m.rust_ns as f64 * 1e-6),
            m.updates.to_string(),
        ]);
    }
    t.print();
}

fn per_example_update_cost() {
    println!("\n-- per-example Algorithm-1 cost (pure Rust hot loop) --");
    let mut t = Table::new(&["dim", "ns/example"]);
    for d in [21usize, 300, 784] {
        let ds_name = match d {
            21 => "waveform",
            300 => "w3a",
            _ => "mnist89",
        };
        let ds = load_dataset_sized(ds_name, 42, 0.2).expect("dataset");
        let train: Vec<Example> = ds.train;
        let opts = TrainOptions::default();
        let stats = bench(1, 5, || {
            let m = StreamSvm::fit(train.iter(), ds.dim, &opts);
            std::hint::black_box(m.radius());
        });
        t.row(&[
            d.to_string(),
            format!("{:.0}", stats.mean.as_nanos() as f64 / train.len() as f64),
        ]);
    }
    t.print();
}

fn serving_latency() {
    println!("\n-- serving latency (predict service, 4 clients) --");
    let ds = load_dataset_sized("mnist01", 42, 0.1).expect("dataset");
    let model = StreamSvm::fit(ds.train.iter(), ds.dim, &TrainOptions::default().with_c(10.0));
    let mut t = Table::new(&["backend", "batch", "req/s", "p50", "p99", "mean fill"]);
    for (label, use_rt, batch) in [
        ("pure", false, 64usize),
        ("pjrt", true, 64),
        ("pjrt", true, 256),
    ] {
        let mut rt = if use_rt { Runtime::open_default().ok() } else { None };
        if use_rt && rt.is_none() {
            continue;
        }
        let svc = PredictService::new(
            model.weights().to_vec(),
            ServiceConfig { batch, ..Default::default() },
        );
        let client = svc.client();
        let test = std::sync::Arc::new(ds.test.clone());
        let n = 4000usize;
        let t0 = std::time::Instant::now();
        let workers: Vec<_> = (0..4)
            .map(|k| {
                let c = client.clone();
                let test = test.clone();
                std::thread::spawn(move || {
                    for i in 0..n / 4 {
                        let e = &test[(k * 31 + i * 7) % test.len()];
                        let _ = c.score(e.x.dense().into_owned()).unwrap();
                    }
                })
            })
            .collect();
        drop(client);
        let stats = svc.run(rt.as_mut()).expect("service");
        for w in workers {
            w.join().unwrap();
        }
        let wall = t0.elapsed();
        t.row(&[
            label.to_string(),
            batch.to_string(),
            format!("{:.0}", n as f64 / wall.as_secs_f64()),
            format!("{:?}", stats.latency.quantile(0.5)),
            format!("{:?}", stats.latency.quantile(0.99)),
            format!("{:.1}", stats.mean_batch_fill()),
        ]);
    }
    t.print();
}

fn main() {
    let full = std::env::var("STREAMSVM_BENCH_FULL").is_ok();
    println!("== throughput / latency ablations (full={full}) ==");
    pipeline_throughput("mnist89", if full { 1.0 } else { 0.2 });
    pipeline_throughput("ijcnn", if full { 1.0 } else { 0.2 });
    per_example_update_cost();
    serving_latency();
}
