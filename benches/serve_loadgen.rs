//! Serving bench: start the network server in-process, drive it with
//! the paced loadgen at increasing target QPS, and report throughput,
//! latency quantiles and shed rate per step. The final (heaviest) step
//! is written to `BENCH_serve.json`.
//!
//! Run: `cargo bench --bench serve_loadgen`
//! (`STREAMSVM_BENCH_FULL=1` for the paper-scale sweep.)

use std::path::Path;
use std::time::Duration;

use streamsvm::bench_util::Table;
use streamsvm::data::registry::load_dataset_sized;
use streamsvm::server::{run_loadgen, serve, LoadgenConfig, ServerConfig};
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

fn main() {
    let full = std::env::var("STREAMSVM_BENCH_FULL").is_ok();
    let frac = if full { 0.5 } else { 0.1 };
    let requests = if full { 20_000 } else { 2_000 };
    let ds = load_dataset_sized("mnist01", 42, frac).expect("dataset");
    let model = StreamSvm::fit(ds.train.iter(), ds.dim, &TrainOptions::default());
    println!(
        "serving mnist01 (dim {}, {} supports), {} requests per step\n",
        ds.dim,
        model.num_support(),
        requests
    );

    let cfg = ServerConfig {
        threads: 8,
        conn_queue: 64,
        train_queue: 8192,
        republish_every: 64,
        read_timeout: Duration::from_secs(5),
        tag: "bench".into(),
        ..Default::default()
    };
    let handle = serve(model, cfg).expect("server start");
    let addr = handle.addr().to_string();

    let mut table = Table::new(&[
        "target rps", "threads", "train%", "achieved rps", "ok", "shed%", "p50", "p90", "p99",
    ]);
    let steps: &[(f64, usize, f64)] = if full {
        &[
            (1_000.0, 4, 0.1),
            (5_000.0, 8, 0.1),
            (20_000.0, 8, 0.1),
            (0.0, 8, 0.1), // unthrottled
            (0.0, 8, 0.5), // train-heavy
        ]
    } else {
        &[(500.0, 4, 0.1), (2_000.0, 4, 0.1), (0.0, 4, 0.25)]
    };
    let mut last = None;
    for &(qps, threads, train_share) in steps {
        let cfg = LoadgenConfig {
            addr: addr.clone(),
            threads,
            requests,
            qps,
            train_share,
            read_timeout: Duration::from_secs(5),
            seed: 42,
        };
        let rep = run_loadgen(&cfg, &ds.test).expect("loadgen");
        table.row(&[
            if qps > 0.0 { format!("{qps:.0}") } else { "∞".into() },
            format!("{threads}"),
            format!("{:.0}", train_share * 100.0),
            format!("{:.0}", rep.qps_achieved()),
            format!("{}", rep.ok),
            format!("{:.1}", rep.shed_rate() * 100.0),
            format!("{:?}", rep.latency.quantile(0.50)),
            format!("{:?}", rep.latency.quantile(0.90)),
            format!("{:?}", rep.latency.quantile(0.99)),
        ]);
        last = Some(rep);
    }
    table.print();

    if let Some(rep) = last {
        rep.write_json(Path::new("BENCH_serve.json")).expect("write BENCH_serve.json");
        println!("\nwrote BENCH_serve.json: {}", rep.summary());
    }
    let report = handle.shutdown().expect("shutdown");
    println!(
        "server: {} ok, {} shed, {} conns ({} shed), trained {} (model v{})",
        report.requests_ok,
        report.requests_shed,
        report.conns_accepted,
        report.conns_shed,
        report.trained,
        report.version
    );
}
