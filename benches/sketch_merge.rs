//! Bench harness for the sketch subsystem: merge-and-reduce tree
//! throughput vs shard count, and codec encode/decode throughput.
//!
//! The tree is the hot path of distributed training hand-off (N shard
//! sketches → one model), so the question is how cheap the reduce stays
//! as the fleet grows: at D floats per merge and ⌈log₂ N⌉ depth the
//! whole fold is O(N·D) — microseconds even at hundreds of shards.
//!
//! `STREAMSVM_BENCH_FULL=1` extends the sweep to 1024 shards.

use streamsvm::bench_util::{bench, Table};
use streamsvm::rng::Pcg32;
use streamsvm::sketch::codec::MebSketch;
use streamsvm::sketch::merge::{merge_ball_tree, merge_sketches};
use streamsvm::svm::ball::BallState;
use streamsvm::svm::TrainOptions;

fn random_ball(d: usize, rng: &mut Pcg32) -> BallState {
    BallState::from_parts(
        (0..d).map(|_| (rng.normal() * 2.0) as f32).collect(),
        1.0 + rng.uniform() * 3.0,
        rng.uniform(),
        1 + rng.below(200),
    )
}

fn merge_tree_throughput(dims: &[usize], shard_counts: &[usize]) {
    println!("\n-- merge-and-reduce tree throughput --");
    let mut t = Table::new(&["dim", "shards", "mean/merge-tree", "sketches/s", "merged R"]);
    for &d in dims {
        for &n in shard_counts {
            let mut rng = Pcg32::seeded(d as u64 * 1000 + n as u64);
            let balls: Vec<BallState> = (0..n).map(|_| random_ball(d, &mut rng)).collect();
            let max_r = balls.iter().map(|b| b.r).fold(0.0f64, f64::max);
            let stats = bench(3, 30, || {
                let root = merge_ball_tree(balls.clone()).unwrap();
                std::hint::black_box(root.r);
            });
            let root = merge_ball_tree(balls.clone()).unwrap();
            assert!(root.r + 1e-9 >= max_r, "tree root must dominate shard radii");
            t.row(&[
                d.to_string(),
                n.to_string(),
                format!("{:?}", stats.mean),
                format!("{:.0}", n as f64 / stats.mean.as_secs_f64()),
                format!("{:.3}", root.r),
            ]);
        }
    }
    t.print();
}

fn sketch_level_merge(shard_counts: &[usize]) {
    println!("\n-- sketch-level merge (validation + tree + provenance) --");
    let d = 128;
    let opts = TrainOptions::default();
    let mut t = Table::new(&["shards", "mean/merge", "sketches/s"]);
    for &n in shard_counts {
        let mut rng = Pcg32::seeded(n as u64);
        let sketches: Vec<MebSketch> = (0..n)
            .map(|i| {
                MebSketch::new(
                    d,
                    Some(random_ball(d, &mut rng)),
                    1000 + i,
                    opts,
                    format!("shard{i}"),
                )
            })
            .collect();
        let stats = bench(3, 30, || {
            let m = merge_sketches(&sketches).unwrap();
            std::hint::black_box(m.seen);
        });
        t.row(&[
            n.to_string(),
            format!("{:?}", stats.mean),
            format!("{:.0}", n as f64 / stats.mean.as_secs_f64()),
        ]);
    }
    t.print();
}

fn codec_throughput(dims: &[usize]) {
    println!("\n-- codec encode/decode throughput --");
    let mut t = Table::new(&["dim", "bytes", "encode", "decode", "MB/s (dec)"]);
    for &d in dims {
        let mut rng = Pcg32::seeded(d as u64);
        let sk = MebSketch::new(
            d,
            Some(random_ball(d, &mut rng)),
            123_456,
            TrainOptions::default().with_c(10.0),
            "bench",
        );
        let bytes = sk.encode();
        let enc = bench(10, 200, || {
            std::hint::black_box(sk.encode().len());
        });
        let dec = bench(10, 200, || {
            let back = MebSketch::decode(&bytes).unwrap();
            std::hint::black_box(back.seen);
        });
        t.row(&[
            d.to_string(),
            bytes.len().to_string(),
            format!("{:?}", enc.mean),
            format!("{:?}", dec.mean),
            format!("{:.0}", bytes.len() as f64 / dec.mean.as_secs_f64() / 1e6),
        ]);
    }
    t.print();
}

fn main() {
    let full = std::env::var("STREAMSVM_BENCH_FULL").is_ok();
    println!("== sketch subsystem benches (full={full}) ==");
    let shard_counts: &[usize] = if full {
        &[2, 4, 8, 16, 32, 64, 128, 256, 512, 1024]
    } else {
        &[2, 4, 8, 16, 32, 64, 128, 256]
    };
    merge_tree_throughput(&[21, 128, 784], shard_counts);
    sketch_level_merge(shard_counts);
    codec_throughput(&[21, 128, 784]);
}
