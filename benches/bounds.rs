//! Bench harness for **§6.1 / Figure 4**: the adversarial and random
//! stream constructions showing lookahead cannot beat the (1+√2)/2 lower
//! bound, and the universal 3/2 upper bound.

use streamsvm::bench_util::time_once;
use streamsvm::exp::bounds;

fn main() {
    let full = std::env::var("STREAMSVM_BENCH_FULL").is_ok();
    let (n, trials) = if full { (2001, 100) } else { (501, 25) };
    println!("== Bounds study (Fig. 4 construction, N={n}, {trials} trials) ==");
    let (pts, wall) = time_once(|| bounds::run(n, &[1, 2, 5, 10, 50], trials, 42));
    bounds::print(&pts);
    println!("\n(wall time {wall:?})");
    println!("shape checks:");
    let mut ok_upper = true;
    let mut ok_lower = true;
    for p in &pts {
        if p.max_ratio > bounds::UPPER_BOUND + 0.05 {
            ok_upper = false;
        }
        if p.order == "adversarial" && p.mean_ratio < bounds::LOWER_BOUND - 0.15 {
            ok_lower = false;
        }
    }
    println!("  all ratios ≤ 3/2 (+tol): {}", if ok_upper { "✓" } else { "✗" });
    println!(
        "  adversarial order pinned near (1+√2)/2 regardless of L: {}",
        if ok_lower { "✓" } else { "✗" }
    );
}
