//! End-to-end driver: the full three-layer system on a real small
//! workload.
//!
//! 1. **Train** on the MNIST-like 0vs1 stream through the coordinator
//!    pipeline — reader thread → bounded channel → block filter (one PJRT
//!    call per 256-row block running the L1 Pallas distance kernel) →
//!    sequential updater. One pass, exact Algorithm-1 semantics.
//! 2. **Serve** batched prediction requests from 8 client threads through
//!    the dynamic batcher, scoring each batch with the AOT `predict`
//!    artifact; report latency percentiles, throughput and accuracy.
//!
//! Requires `make artifacts` (falls back to pure-Rust with a warning).
//!
//! ```sh
//! cargo run --release --example streaming_service
//! ```

use std::time::Instant;

use streamsvm::coordinator::pipeline::{train_stream, ExecMode, PipelineConfig};
use streamsvm::coordinator::service::{PredictService, ServiceConfig};
use streamsvm::coordinator::stream::VecStream;
use streamsvm::data::registry::load_dataset;
use streamsvm::eval::accuracy;
use streamsvm::runtime::Runtime;
use streamsvm::svm::TrainOptions;

fn main() -> streamsvm::Result<()> {
    let ds = load_dataset("mnist01", 42)?;
    println!(
        "== StreamSVM end-to-end: {} ({} train / {} test, dim {}) ==",
        ds.name,
        ds.train.len(),
        ds.test.len(),
        ds.dim
    );

    let mut rt = match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("warning: {e}\n         running in pure-Rust mode");
            None
        }
    };

    // ---- phase 1: one-pass streaming training
    let mode = if rt.is_some() { ExecMode::Filter } else { ExecMode::Pure };
    let cfg = PipelineConfig {
        train: TrainOptions::default().with_c(10.0),
        mode,
        block: None,
        queue: 4,
        ..Default::default()
    };
    let stream = VecStream::of_train(&ds, Some(7));
    let report = train_stream(rt.as_mut(), stream, ds.dim, cfg)?;
    println!("train pipeline [{mode:?}]: {}", report.metrics.summary());
    let test_acc = accuracy(&report.model, &ds.test);
    println!(
        "model: R={:.4}, {} core vectors | single-pass test acc {:.2}%",
        report.model.radius(),
        report.model.num_support(),
        test_acc * 100.0
    );

    // ---- phase 2: batched serving
    let svc = PredictService::new(
        report.model.weights().to_vec(),
        ServiceConfig { batch: 64, ..Default::default() },
    );
    let client = svc.client();
    let test = std::sync::Arc::new(ds.test.clone());
    let n_workers = 8;
    let reqs_per_worker = 2000;
    let t0 = Instant::now();
    let workers: Vec<_> = (0..n_workers)
        .map(|k| {
            let c = client.clone();
            let test = test.clone();
            std::thread::spawn(move || {
                let mut correct = 0usize;
                for i in 0..reqs_per_worker {
                    let e = &test[(k * 97 + i * 13) % test.len()];
                    let s = c.score(e.x.dense().into_owned()).unwrap();
                    if (s >= 0.0) == (e.y > 0.0) {
                        correct += 1;
                    }
                }
                correct
            })
        })
        .collect();
    drop(client);
    let stats = svc.run(rt.as_mut())?;
    let correct: usize = workers.into_iter().map(|w| w.join().unwrap()).sum();
    let wall = t0.elapsed();
    let total = n_workers * reqs_per_worker;
    println!(
        "served {total} requests in {wall:?} ({:.0} req/s, {} batches, mean fill {:.1})",
        total as f64 / wall.as_secs_f64(),
        stats.batches,
        stats.mean_batch_fill()
    );
    println!("latency: {}", stats.latency.summary());
    println!("serving accuracy: {:.2}%", correct as f64 / total as f64 * 100.0);
    Ok(())
}
