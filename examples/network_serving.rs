//! End-to-end network serving demo: train a model, put it behind the
//! TCP server, keep training it through `/train` while `/predict`
//! traffic flows, watch the hot-swap version advance, and shut down
//! gracefully with every accepted example absorbed.
//!
//! Run: `cargo run --release --example network_serving`

use std::time::Duration;

use streamsvm::data::registry::load_dataset_sized;
use streamsvm::error::Result;
use streamsvm::eval::accuracy;
use streamsvm::server::{serve, LoadClient, ServerConfig};
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

fn main() -> Result<()> {
    let ds = load_dataset_sized("synthA", 42, 0.2)?;
    // warm-start on the first half of the train split; the second half
    // arrives later as live /train traffic
    let half = ds.train.len() / 2;
    let model = StreamSvm::fit(ds.train[..half].iter(), ds.dim, &TrainOptions::default());
    println!(
        "warm start: {} examples, test acc {:.2}%",
        half,
        accuracy(&model, &ds.test) * 100.0
    );

    let handle = serve(
        model,
        ServerConfig {
            threads: 4,
            republish_every: 16,
            tag: "demo".into(),
            ..Default::default()
        },
    )?;
    let addr = handle.addr();
    println!("serving on http://{addr}/");

    let mut client = LoadClient::connect(addr, Duration::from_secs(2))?;

    // score a few test points against the warm-start snapshot
    for e in ds.test.iter().take(3) {
        let o = client.predict_features(&e.x)?;
        println!(
            "  predict → status {} score {:+.4} (snapshot v{})",
            o.status,
            o.score.unwrap_or(f64::NAN),
            o.version.unwrap_or(0)
        );
    }

    // stream the second half through /train: the server learns live
    let mut accepted = 0;
    for e in &ds.train[half..] {
        if client.train_features(&e.x, e.y)?.status == 202 {
            accepted += 1;
        }
    }
    println!("streamed {} live training examples ({} accepted)", ds.train.len() - half, accepted);

    // the hot-swap cell republished while we trained
    let o = client.predict_features(&ds.test[0].x)?;
    println!(
        "  predict after live training → score {:+.4} (snapshot v{})",
        o.score.unwrap_or(f64::NAN),
        o.version.unwrap_or(0)
    );
    let stats = client.stats()?;
    println!(
        "  /stats: version={} trained={}",
        stats.get("version").and_then(|v| v.as_f64()).unwrap_or(0.0),
        stats.get("trained").and_then(|v| v.as_f64()).unwrap_or(0.0),
    );
    drop(client);

    let report = handle.shutdown()?;
    println!(
        "shutdown: trained {} live examples, final snapshot v{}, test acc {:.2}%",
        report.trained,
        report.version,
        accuracy(&report.model, &ds.test) * 100.0
    );
    Ok(())
}
