//! Checkpoint / resume / merge walkthrough: the sketch subsystem
//! end-to-end on a real dataset.
//!
//! 1. Train one pass over Synthetic A, checkpointing every 2,000
//!    examples through the streaming pipeline.
//! 2. "Crash": throw the trained model away, reload the last checkpoint
//!    file, replay the remaining stream — and verify the resumed weights
//!    are **bit-identical** to the uninterrupted run.
//! 3. Split the same stream across 4 shards, snapshot each shard to its
//!    own sketch file, merge the files through the merge-and-reduce
//!    tree, and compare accuracies.
//!
//! ```sh
//! cargo run --release --example checkpoint_resume
//! ```

use std::path::PathBuf;

use streamsvm::coordinator::pipeline::{train_stream_ckpt, ExecMode, PipelineConfig};
use streamsvm::coordinator::stream::VecStream;
use streamsvm::data::registry::load_dataset_sized;
use streamsvm::eval::accuracy;
use streamsvm::sketch::checkpoint::{resume_fit, CheckpointConfig, Checkpointer};
use streamsvm::sketch::codec::MebSketch;
use streamsvm::sketch::merge::merge_sketches;
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

fn main() -> streamsvm::Result<()> {
    let dir = std::env::temp_dir().join(format!("streamsvm_demo_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let ds = load_dataset_sized("synthA", 42, 0.5)?;
    let opts = TrainOptions::default();
    println!(
        "== checkpoint/resume on {} ({} train, dim {}) ==",
        ds.name,
        ds.train.len(),
        ds.dim
    );

    // ---- 1. checkpointed one-pass training through the pipeline
    let ckpt_path: PathBuf = dir.join("run.meb");
    let mut ck = Checkpointer::new(CheckpointConfig {
        every: 2_000,
        path: ckpt_path.clone(),
        tag: ds.name.clone(),
    });
    let cfg = PipelineConfig { train: opts, mode: ExecMode::Pure, ..Default::default() };
    let stream = VecStream::of_train(&ds, None);
    let report = train_stream_ckpt(None, stream, ds.dim, cfg, Some(&mut ck))?;
    println!(
        "trained: R={:.4}, {} core vectors | {} checkpoints, last at example {}",
        report.model.radius(),
        report.model.num_support(),
        ck.saves(),
        ck.last_saved()
    );

    // ---- 2. crash + resume from the last sketch on disk
    let sk = MebSketch::read_from(&ckpt_path)?;
    println!("reloaded {}: {}", ckpt_path.display(), sk.summary());
    let resumed = resume_fit(&sk, VecStream::of_train(&ds, None));
    let identical = resumed.weights() == report.model.weights()
        && resumed.radius().to_bits() == report.model.radius().to_bits();
    println!(
        "resumed from example {}: weights bit-identical to uninterrupted run: {}",
        sk.seen,
        if identical { "YES" } else { "NO (bug!)" }
    );
    assert!(identical);

    // ---- 3. shard, snapshot each shard, merge the sketch files
    let shards = 4usize;
    let mut files = Vec::new();
    for s in 0..shards {
        let mut m = StreamSvm::new(ds.dim, opts);
        for e in ds.train.iter().skip(s).step_by(shards) {
            m.observe_view(e.x.view(), e.y);
        }
        let path = dir.join(format!("shard{s}.meb"));
        MebSketch::from_model(&m, format!("shard{s}")).write_to(&path)?;
        files.push(path);
    }
    let sketches: streamsvm::Result<Vec<MebSketch>> =
        files.iter().map(|p| MebSketch::read_from(p)).collect();
    let merged = merge_sketches(&sketches?)?;
    let merged_model = merged.to_model();
    println!(
        "merged {} shard files: {} | single-pass acc {:.2}% vs merged acc {:.2}%",
        shards,
        merged.summary(),
        accuracy(&report.model, &ds.test) * 100.0,
        accuracy(&merged_model, &ds.test) * 100.0
    );

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
