//! Lookahead study (Figure-3 style) on the Waveform generator: accuracy
//! mean ± std over stream permutations as L grows, plus the SV count.
//!
//! ```sh
//! cargo run --release --example lookahead_study
//! ```

use streamsvm::bench_util::Table;
use streamsvm::data::registry::load_dataset_sized;
use streamsvm::data::Example;
use streamsvm::eval::{accuracy, mean_std};
use streamsvm::rng::Pcg32;
use streamsvm::svm::lookahead::LookaheadSvm;
use streamsvm::svm::TrainOptions;

fn main() -> streamsvm::Result<()> {
    let ds = load_dataset_sized("waveform", 42, 1.0)?;
    println!("lookahead sweep on {} ({} train)", ds.name, ds.train.len());
    let perms = 25;
    let mut table = Table::new(&["L", "acc mean %", "acc std %", "mean #SV", "merges"]);
    for l in [1usize, 2, 5, 10, 20, 50] {
        let opts = TrainOptions::default().with_lookahead(l);
        let mut accs = Vec::new();
        let mut svs = Vec::new();
        let mut merges = Vec::new();
        for p in 0..perms {
            let mut order: Vec<usize> = (0..ds.train.len()).collect();
            Pcg32::new(p as u64, 1).shuffle(&mut order);
            let stream: Vec<Example> = order.iter().map(|&i| ds.train[i].clone()).collect();
            let m = LookaheadSvm::fit(stream.iter(), ds.dim, &opts);
            accs.push(accuracy(&m, &ds.test));
            svs.push(m.num_support() as f64);
            merges.push(m.num_merges() as f64);
        }
        let (am, asd) = mean_std(&accs);
        let (sm, _) = mean_std(&svs);
        let (mm, _) = mean_std(&merges);
        table.row(&[
            l.to_string(),
            format!("{:.2}", am * 100.0),
            format!("{:.2}", asd * 100.0),
            format!("{sm:.0}"),
            format!("{mm:.0}"),
        ]);
    }
    table.print();
    println!("\nexpected shape (paper Fig. 3): accuracy rises and variance");
    println!("shrinks with L; convergence by L≈10.");
    Ok(())
}
