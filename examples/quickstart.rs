//! Quickstart: train a one-pass StreamSVM on Synthetic A and compare
//! Algorithm 1 vs Algorithm 2 (lookahead) vs a batch solver.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use streamsvm::baselines::batch_l2svm::{BatchL2Svm, BatchL2SvmOptions};
use streamsvm::data::registry::load_dataset;
use streamsvm::eval::accuracy;
use streamsvm::svm::lookahead::LookaheadSvm;
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

fn main() -> streamsvm::Result<()> {
    let ds = load_dataset("synthA", 42)?;
    println!(
        "dataset {}: {} train / {} test, dim {}",
        ds.name,
        ds.train.len(),
        ds.test.len(),
        ds.dim
    );

    // Algorithm 1: one pass, O(D) state.
    let opts = TrainOptions::default();
    let t = std::time::Instant::now();
    let algo1 = StreamSvm::fit(ds.train.iter(), ds.dim, &opts);
    println!(
        "Algorithm 1: acc {:.2}%  (R={:.3}, {} core vectors, {:?})",
        accuracy(&algo1, &ds.test) * 100.0,
        algo1.radius(),
        algo1.num_support(),
        t.elapsed()
    );

    // Algorithm 2: one pass with a lookahead buffer of 10.
    let t = std::time::Instant::now();
    let algo2 = LookaheadSvm::fit(ds.train.iter(), ds.dim, &opts.with_lookahead(10));
    println!(
        "Algorithm 2 (L=10): acc {:.2}%  (R={:.3}, {} merges, {:?})",
        accuracy(&algo2, &ds.test) * 100.0,
        algo2.radius(),
        algo2.num_merges(),
        t.elapsed()
    );

    // Batch reference (all data in memory, multiple epochs).
    let t = std::time::Instant::now();
    let batch = BatchL2Svm::fit(&ds.train, ds.dim, &BatchL2SvmOptions::default());
    println!(
        "batch l2-SVM: acc {:.2}%  ({} epochs, {:?})",
        accuracy(&batch, &ds.test) * 100.0,
        batch.epochs_run(),
        t.elapsed()
    );
    Ok(())
}
