//! Kernelized StreamSVM (paper §4.2): one-pass learning of non-linear
//! concepts with an RBF kernel, where the linear variant fails.
//!
//! Two classic workloads: XOR and concentric circles.
//!
//! ```sh
//! cargo run --release --example kernelized
//! ```

use streamsvm::data::Example;
use streamsvm::eval::accuracy;
use streamsvm::rng::Pcg32;
use streamsvm::svm::kernelfn::Kernel;
use streamsvm::svm::kernelized::KernelStreamSvm;
use streamsvm::svm::streamsvm::StreamSvm;
use streamsvm::svm::TrainOptions;

fn xor(n: usize, seed: u64) -> Vec<Example> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|_| {
            let a = rng.bernoulli(0.5);
            let b = rng.bernoulli(0.5);
            let y = if a ^ b { 1.0 } else { -1.0 };
            Example::new(
                vec![
                    (if a { 1.0 } else { -1.0 }) + rng.normal() as f32 * 0.2,
                    (if b { 1.0 } else { -1.0 }) + rng.normal() as f32 * 0.2,
                ],
                y,
            )
        })
        .collect()
}

fn circles(n: usize, seed: u64) -> Vec<Example> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|_| {
            let y = rng.label(0.5);
            let r = if y > 0.0 { 1.0 } else { 2.2 };
            let theta = rng.uniform() * std::f64::consts::TAU;
            Example::new(
                vec![
                    (r * theta.cos() + rng.normal() * 0.15) as f32,
                    (r * theta.sin() + rng.normal() * 0.15) as f32,
                ],
                y,
            )
        })
        .collect()
}

fn run(name: &str, train: &[Example], test: &[Example]) {
    let opts = TrainOptions::default().with_c(100.0);
    let lin = StreamSvm::fit(train.iter(), 2, &opts);
    let rbf = KernelStreamSvm::fit(train.iter(), Kernel::Rbf { gamma: 1.2 }, &opts);
    println!(
        "{name:>9}: linear {:>5.1}%  |  RBF {:>5.1}%  ({} SVs, one pass)",
        accuracy(&lin, test) * 100.0,
        accuracy(&rbf, test) * 100.0,
        rbf.num_support()
    );
}

fn main() {
    println!("one-pass kernelized StreamSVM vs linear on non-linear concepts\n");
    run("xor", &xor(3000, 1), &xor(800, 2));
    run("circles", &circles(3000, 3), &circles(800, 4));
    println!("\nexpected: linear ≈ chance, RBF ≈ 95%+ — still one pass, O(M) per example.");
}
